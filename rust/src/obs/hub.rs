//! [`MetricsHub`]: per-step gauge/counter sampling with a fixed-capacity
//! ring buffer and a Prometheus text-exposition renderer.
//!
//! The hub is the single shared sink between the train loop (producer:
//! one [`StepSample`] per step) and the [`ObsServer`](super::ObsServer)
//! scrape thread (consumer: renders the latest gauges plus lifetime
//! counters). Recording holds a short uncontended mutex over the
//! pre-allocated ring — no allocation ever happens on the hot path, and
//! a full ring drops the sample and counts it (`samples_dropped`), the
//! same contract as `trace::event`.

use crate::metrics::Histogram;
use crate::trace::PhaseStat;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// Smoothing factor of the step-time EWMA gauge.
const EWMA_ALPHA: f64 = 0.1;

/// Default ring capacity: enough for the recent scrape window without
/// unbounded growth on long runs (`--memlog` keeps the full timeline).
const DEFAULT_RING_CAPACITY: usize = 1024;

/// One train step's observed memory/queue/timing gauges.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepSample {
    /// Global step index (monotonic across epochs and replans).
    pub step: u64,
    /// Observed arena slab high-water mark: max concurrent live bytes of
    /// the resident lifetimes replayed over the step's schedule.
    pub slab_high_water_bytes: u64,
    /// Host-spill pool resident high-water within the step (0 when the
    /// plan does not spill).
    pub host_resident_bytes: u64,
    /// Runtime staging-arena occupancy after the step.
    pub scratch_used_bytes: u64,
    /// Runtime staging-arena run-global high-water mark.
    pub scratch_high_water_bytes: u64,
    /// Link retries accumulated so far (backlog of retried transfers).
    pub link_retry_backlog: u64,
    /// Decoded batches queued between the loader and the trainer.
    pub loader_queue_depth: u64,
    /// Degradation-ladder rung currently applied (0 = healthy).
    pub degrade_rung: u64,
    /// Wall seconds of the step.
    pub step_secs: f64,
}

impl StepSample {
    /// CSV header of the `--memlog` per-step timeline (matches
    /// [`StepSample::to_csv_row`] column for column).
    pub fn csv_header() -> &'static str {
        "step,slab_high_water_bytes,host_resident_bytes,scratch_used_bytes,\
         scratch_high_water_bytes,link_retry_backlog,loader_queue_depth,\
         degrade_rung,step_secs"
    }

    /// One `--memlog` CSV row (no trailing newline).
    pub fn to_csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{},{:.6}",
            self.step,
            self.slab_high_water_bytes,
            self.host_resident_bytes,
            self.scratch_used_bytes,
            self.scratch_high_water_bytes,
            self.link_retry_backlog,
            self.loader_queue_depth,
            self.degrade_rung,
            self.step_secs,
        )
    }
}

/// Fixed-capacity sample ring: pre-allocated once, never grows. The
/// latest sample is kept separately so scrape gauges stay current even
/// while the ring is saturated and dropping.
struct Ring {
    samples: Vec<StepSample>,
    capacity: usize,
    dropped: u64,
    latest: Option<StepSample>,
}

/// Sliding admit/shed window behind serve-mode readiness: a fixed-size
/// boolean ring (true = shed) recording the most recent admission
/// decisions. Pre-allocated once; recording overwrites in place.
struct ShedWindow {
    slots: Vec<bool>,
    capacity: usize,
    /// Next write position.
    head: usize,
    /// Valid slots (≤ capacity).
    len: usize,
}

impl ShedWindow {
    fn new(capacity: usize) -> ShedWindow {
        let capacity = capacity.max(1);
        ShedWindow { slots: vec![false; capacity], capacity, head: 0, len: 0 }
    }

    fn push(&mut self, shed: bool) {
        self.slots[self.head] = shed;
        self.head = (self.head + 1) % self.capacity;
        self.len = (self.len + 1).min(self.capacity);
    }

    /// Shed fraction over the valid window (0.0 when empty).
    fn rate(&self) -> f64 {
        if self.len == 0 {
            return 0.0;
        }
        let sheds = self.slots[..self.len.min(self.capacity)]
            .iter()
            .filter(|&&s| s)
            .count();
        sheds as f64 / self.len as f64
    }
}

/// Shared metrics sink: per-step samples, lifetime counters, readiness.
///
/// Cheap to share (`Arc<MetricsHub>`); all mutation goes through `&self`.
pub struct MetricsHub {
    ring: Mutex<Ring>,
    steps_total: AtomicU64,
    degrade_events_total: AtomicU64,
    degrade_rungs_total: AtomicU64,
    /// Step-time EWMA, stored as `f64::to_bits` (NaN bits until the
    /// first sample lands).
    ewma_step_bits: AtomicU64,
    /// Run-global maxima across every recorded sample (survive ring
    /// wrap-around and drops).
    max_slab_high_water: AtomicU64,
    max_host_resident: AtomicU64,
    degraded: AtomicBool,
    watchdog_fired: AtomicBool,
    /// Serve-mode gauges (queue depth, admit/shed counters, batch-size
    /// histogram, shed window). Inert — and absent from the exposition —
    /// until [`MetricsHub::enable_serve_mode`] is called.
    serve_mode: AtomicBool,
    serve_queue_depth: AtomicU64,
    serve_admitted_total: AtomicU64,
    serve_shed_total: AtomicU64,
    serve_batches_total: AtomicU64,
    serve_batch_hist: Mutex<Histogram>,
    shed_window: Mutex<ShedWindow>,
    /// Per-phase p50/p95/p99 tables promoted from the trace layer,
    /// rendered as `optorch_phase_seconds{phase,quantile}` gauges.
    phase_stats: Mutex<Vec<PhaseStat>>,
}

impl MetricsHub {
    pub fn new() -> MetricsHub {
        MetricsHub::with_capacity(DEFAULT_RING_CAPACITY)
    }

    /// A hub whose ring holds at most `capacity` samples (further
    /// samples are dropped-and-counted, never allocated).
    pub fn with_capacity(capacity: usize) -> MetricsHub {
        let capacity = capacity.max(1);
        MetricsHub {
            ring: Mutex::new(Ring {
                samples: Vec::with_capacity(capacity),
                capacity,
                dropped: 0,
                latest: None,
            }),
            steps_total: AtomicU64::new(0),
            degrade_events_total: AtomicU64::new(0),
            degrade_rungs_total: AtomicU64::new(0),
            ewma_step_bits: AtomicU64::new(f64::NAN.to_bits()),
            max_slab_high_water: AtomicU64::new(0),
            max_host_resident: AtomicU64::new(0),
            degraded: AtomicBool::new(false),
            watchdog_fired: AtomicBool::new(false),
            serve_mode: AtomicBool::new(false),
            serve_queue_depth: AtomicU64::new(0),
            serve_admitted_total: AtomicU64::new(0),
            serve_shed_total: AtomicU64::new(0),
            serve_batches_total: AtomicU64::new(0),
            serve_batch_hist: Mutex::new(Histogram::new()),
            shed_window: Mutex::new(ShedWindow::new(1)),
            phase_stats: Mutex::new(Vec::new()),
        }
    }

    /// Switch the hub into serve mode: the serve gauge/counter series
    /// join the exposition, and readiness additionally requires a zero
    /// shed rate over the most recent `shed_window` admission decisions.
    pub fn enable_serve_mode(&self, shed_window: usize) {
        *self.shed_window.lock().unwrap_or_else(|p| p.into_inner()) =
            ShedWindow::new(shed_window);
        self.serve_mode.store(true, Ordering::Relaxed);
    }

    /// Record one admitted request.
    pub fn note_admitted(&self) {
        self.serve_admitted_total.fetch_add(1, Ordering::Relaxed);
        self.shed_window.lock().unwrap_or_else(|p| p.into_inner()).push(false);
    }

    /// Record one shed request.
    pub fn note_shed(&self) {
        self.serve_shed_total.fetch_add(1, Ordering::Relaxed);
        self.shed_window.lock().unwrap_or_else(|p| p.into_inner()).push(true);
    }

    /// Refresh the queue-depth gauge.
    pub fn set_queue_depth(&self, depth: u64) {
        self.serve_queue_depth.store(depth, Ordering::Relaxed);
    }

    /// Record one dispatched micro-batch of `size` requests.
    pub fn record_batch(&self, size: u64) {
        self.serve_batches_total.fetch_add(1, Ordering::Relaxed);
        self.serve_batch_hist.lock().unwrap_or_else(|p| p.into_inner()).record(size);
    }

    /// Shed fraction over the sliding admission window (0.0 while empty
    /// or outside serve mode).
    pub fn shed_rate_window(&self) -> f64 {
        if !self.serve_mode.load(Ordering::Relaxed) {
            return 0.0;
        }
        self.shed_window.lock().unwrap_or_else(|p| p.into_inner()).rate()
    }

    pub fn admitted(&self) -> u64 {
        self.serve_admitted_total.load(Ordering::Relaxed)
    }

    pub fn shed(&self) -> u64 {
        self.serve_shed_total.load(Ordering::Relaxed)
    }

    /// Replace the per-phase quantile tables rendered on `/metrics` as
    /// `optorch_phase_seconds{phase,quantile}` gauges. The trainer pushes
    /// the trace layer's tables here at run end; the serve loop pushes
    /// its own phases live.
    pub fn update_phase_stats(&self, stats: &[PhaseStat]) {
        let mut held = self.phase_stats.lock().unwrap_or_else(|p| p.into_inner());
        held.clear();
        held.extend_from_slice(stats);
    }

    /// Record one train step. No allocation: a full ring drops the
    /// sample and bumps the drop counter; `latest` and the run-global
    /// maxima are still refreshed so gauges never go stale.
    pub fn record_step(&self, sample: StepSample) {
        {
            let mut ring = self.ring.lock().unwrap_or_else(|p| p.into_inner());
            if ring.samples.len() < ring.capacity {
                ring.samples.push(sample);
            } else {
                ring.dropped += 1;
            }
            ring.latest = Some(sample);
        }
        self.steps_total.fetch_add(1, Ordering::Relaxed);
        self.max_slab_high_water.fetch_max(sample.slab_high_water_bytes, Ordering::Relaxed);
        self.max_host_resident.fetch_max(sample.host_resident_bytes, Ordering::Relaxed);
        // Single-producer EWMA: the train loop is the only writer, so a
        // load/store pair is race-free in practice and harmlessly
        // approximate otherwise.
        let prev = f64::from_bits(self.ewma_step_bits.load(Ordering::Relaxed));
        let next = if prev.is_nan() {
            sample.step_secs
        } else {
            (1.0 - EWMA_ALPHA) * prev + EWMA_ALPHA * sample.step_secs
        };
        self.ewma_step_bits.store(next.to_bits(), Ordering::Relaxed);
    }

    /// Mark a degradation episode: `rungs` ladder actions were applied.
    pub fn note_degrade_event(&self, rungs: u64) {
        self.degrade_events_total.fetch_add(1, Ordering::Relaxed);
        self.degrade_rungs_total.fetch_add(rungs, Ordering::Relaxed);
        self.set_degraded(true);
    }

    /// Flip the `/readyz` degraded latch (set while the `run_degraded`
    /// ladder's plan is live).
    pub fn set_degraded(&self, degraded: bool) {
        self.degraded.store(degraded, Ordering::Relaxed);
    }

    /// Latch the loader-watchdog failure; `/readyz` reports 503 from
    /// here on (a fired watchdog is not recoverable mid-run).
    pub fn set_watchdog_fired(&self) {
        self.watchdog_fired.store(true, Ordering::Relaxed);
    }

    /// Ready = no active degradation ladder, no fired watchdog, and — in
    /// serve mode — a zero shed rate over the sliding admission window.
    pub fn is_ready(&self) -> bool {
        !self.degraded.load(Ordering::Relaxed)
            && !self.watchdog_fired.load(Ordering::Relaxed)
            && self.shed_rate_window() == 0.0
    }

    pub fn steps(&self) -> u64 {
        self.steps_total.load(Ordering::Relaxed)
    }

    /// Samples dropped because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.ring.lock().unwrap_or_else(|p| p.into_inner()).dropped
    }

    /// Samples currently held (≤ capacity, never more).
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap_or_else(|p| p.into_inner()).samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.ring.lock().unwrap_or_else(|p| p.into_inner()).capacity
    }

    /// The most recently recorded sample (kept fresh even when full).
    pub fn latest(&self) -> Option<StepSample> {
        self.ring.lock().unwrap_or_else(|p| p.into_inner()).latest
    }

    /// Step-time EWMA in seconds; `None` before the first sample.
    pub fn ewma_step_secs(&self) -> Option<f64> {
        let v = f64::from_bits(self.ewma_step_bits.load(Ordering::Relaxed));
        if v.is_nan() {
            None
        } else {
            Some(v)
        }
    }

    /// Run-global observed slab high-water across all samples.
    pub fn max_slab_high_water_bytes(&self) -> u64 {
        self.max_slab_high_water.load(Ordering::Relaxed)
    }

    /// Run-global observed host-resident high-water across all samples.
    pub fn max_host_resident_bytes(&self) -> u64 {
        self.max_host_resident.load(Ordering::Relaxed)
    }

    pub fn degrade_events(&self) -> u64 {
        self.degrade_events_total.load(Ordering::Relaxed)
    }

    pub fn degrade_rungs(&self) -> u64 {
        self.degrade_rungs_total.load(Ordering::Relaxed)
    }

    /// Render every series in Prometheus text-exposition format 0.0.4
    /// (`# HELP` / `# TYPE` preamble per metric, one sample each).
    pub fn prometheus_text(&self) -> String {
        let latest = self.latest().unwrap_or_default();
        let mut out = String::with_capacity(2048);
        let mut gauge = |name: &str, help: &str, value: f64| {
            push_metric(&mut out, name, help, "gauge", value);
        };
        gauge("optorch_up", "Whether the trainer run is live.", 1.0);
        gauge(
            "optorch_ready",
            "Whether the run is healthy (no degradation ladder, no fired watchdog).",
            if self.is_ready() { 1.0 } else { 0.0 },
        );
        gauge(
            "optorch_arena_slab_high_water_bytes",
            "Observed arena slab high-water mark of the last step.",
            latest.slab_high_water_bytes as f64,
        );
        gauge(
            "optorch_arena_slab_high_water_max_bytes",
            "Run-global observed arena slab high-water mark.",
            self.max_slab_high_water_bytes() as f64,
        );
        gauge(
            "optorch_arena_scratch_used_bytes",
            "Runtime staging-arena occupancy after the last step.",
            latest.scratch_used_bytes as f64,
        );
        gauge(
            "optorch_arena_scratch_high_water_bytes",
            "Runtime staging-arena run-global high-water mark.",
            latest.scratch_high_water_bytes as f64,
        );
        gauge(
            "optorch_host_resident_bytes",
            "Host-spill pool resident high-water within the last step.",
            latest.host_resident_bytes as f64,
        );
        gauge(
            "optorch_host_resident_max_bytes",
            "Run-global observed host-spill resident high-water mark.",
            self.max_host_resident_bytes() as f64,
        );
        gauge(
            "optorch_link_retry_backlog",
            "Host-link transfer retries accumulated so far.",
            latest.link_retry_backlog as f64,
        );
        gauge(
            "optorch_loader_queue_depth",
            "Decoded batches queued between the loader and the trainer.",
            latest.loader_queue_depth as f64,
        );
        gauge(
            "optorch_degrade_rung",
            "Degradation-ladder rung currently applied (0 = healthy).",
            latest.degrade_rung as f64,
        );
        gauge(
            "optorch_step_time_ewma_seconds",
            "Exponentially weighted moving average of step wall time.",
            self.ewma_step_secs().unwrap_or(0.0),
        );
        let mut counter = |name: &str, help: &str, value: u64| {
            push_metric(&mut out, name, help, "counter", value as f64);
        };
        counter("optorch_steps_total", "Train steps completed.", self.steps());
        counter(
            "optorch_samples_dropped_total",
            "Step samples dropped because the metrics ring was full.",
            self.dropped(),
        );
        counter(
            "optorch_degrade_events_total",
            "Degradation-ladder episodes triggered.",
            self.degrade_events(),
        );
        counter(
            "optorch_degrade_rungs_total",
            "Degradation-ladder rungs applied across all episodes.",
            self.degrade_rungs(),
        );
        self.push_phase_series(&mut out);
        if self.serve_mode.load(Ordering::Relaxed) {
            self.push_serve_series(&mut out);
        }
        out
    }

    /// `optorch_phase_seconds{phase,quantile}` gauges — one labeled sample
    /// per stored phase × {0.5, 0.95, 0.99}, one shared HELP/TYPE header.
    fn push_phase_series(&self, out: &mut String) {
        let phases = self.phase_stats.lock().unwrap_or_else(|p| p.into_inner());
        if phases.is_empty() {
            return;
        }
        push_header(
            out,
            "optorch_phase_seconds",
            "Per-phase wall-time quantiles from the trace layer.",
            "gauge",
        );
        for ps in phases.iter() {
            let phase = sanitize_label(&ps.name);
            for (q, v) in [("0.5", ps.p50_secs), ("0.95", ps.p95_secs), ("0.99", ps.p99_secs)] {
                push_labeled_metric(
                    out,
                    "optorch_phase_seconds",
                    &[("phase", &phase), ("quantile", q)],
                    v,
                );
            }
        }
    }

    /// Serve-mode series: queue depth, windowed shed rate, admit/shed/batch
    /// counters, and labeled batch-size quantiles.
    fn push_serve_series(&self, out: &mut String) {
        push_metric(
            out,
            "optorch_serve_queue_depth",
            "Pending requests in the serve queue.",
            "gauge",
            self.serve_queue_depth.load(Ordering::Relaxed) as f64,
        );
        push_metric(
            out,
            "optorch_serve_shed_rate_window",
            "Shed fraction over the sliding admission window.",
            "gauge",
            self.shed_rate_window(),
        );
        push_metric(
            out,
            "optorch_serve_admitted_total",
            "Requests admitted by the serving admission controller.",
            "counter",
            self.admitted() as f64,
        );
        push_metric(
            out,
            "optorch_serve_shed_total",
            "Requests shed by the serving admission controller.",
            "counter",
            self.shed() as f64,
        );
        push_metric(
            out,
            "optorch_serve_batches_total",
            "Micro-batches dispatched by the serving batcher.",
            "counter",
            self.serve_batches_total.load(Ordering::Relaxed) as f64,
        );
        let hist = self.serve_batch_hist.lock().unwrap_or_else(|p| p.into_inner());
        if hist.count() > 0 {
            push_header(
                out,
                "optorch_serve_batch_size",
                "Dispatched micro-batch size quantiles.",
                "gauge",
            );
            for (q, v) in [("0.5", hist.p50()), ("0.95", hist.p95()), ("0.99", hist.p99())] {
                push_labeled_metric(
                    out,
                    "optorch_serve_batch_size",
                    &[("quantile", q)],
                    v as f64,
                );
            }
        }
    }
}

impl Default for MetricsHub {
    fn default() -> MetricsHub {
        MetricsHub::new()
    }
}

/// Append one metric in exposition format. Values are integral gauges or
/// counters almost everywhere; format with enough precision for the EWMA
/// without trailing-zero noise on integers.
fn push_metric(out: &mut String, name: &str, help: &str, kind: &str, value: f64) {
    push_header(out, name, help, kind);
    out.push_str(name);
    out.push(' ');
    push_value(out, value);
}

/// `# HELP` / `# TYPE` preamble alone — for metrics that emit several
/// labeled samples under one name.
fn push_header(out: &mut String, name: &str, help: &str, kind: &str) {
    out.push_str("# HELP ");
    out.push_str(name);
    out.push(' ');
    out.push_str(help);
    out.push_str("\n# TYPE ");
    out.push_str(name);
    out.push(' ');
    out.push_str(kind);
    out.push('\n');
}

/// One labeled sample line: `name{k="v",...} value`. Label values must be
/// pre-sanitized ([`sanitize_label`]) — no spaces, quotes, or backslashes.
fn push_labeled_metric(out: &mut String, name: &str, labels: &[(&str, &str)], value: f64) {
    out.push_str(name);
    out.push('{');
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(k);
        out.push_str("=\"");
        out.push_str(v);
        out.push('"');
    }
    out.push_str("} ");
    push_value(out, value);
}

fn push_value(out: &mut String, value: f64) {
    if value.fract() == 0.0 && value.abs() < 9e15 {
        out.push_str(&format!("{}", value as i64));
    } else {
        out.push_str(&format!("{value:.9}"));
    }
    out.push('\n');
}

/// Clamp a free-form phase name into a safe exposition label value:
/// alphanumerics plus `_-.:` survive, everything else becomes `_`.
fn sanitize_label(raw: &str) -> String {
    raw.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.' | ':') {
                c
            } else {
                '_'
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(step: u64) -> StepSample {
        StepSample {
            step,
            slab_high_water_bytes: 1000 + step,
            host_resident_bytes: 10 * step,
            scratch_used_bytes: 64,
            scratch_high_water_bytes: 128,
            link_retry_backlog: 1,
            loader_queue_depth: 2,
            degrade_rung: 0,
            step_secs: 0.010,
        }
    }

    #[test]
    fn ring_drops_and_counts_when_full() {
        let hub = MetricsHub::with_capacity(4);
        for i in 0..10 {
            hub.record_step(sample(i));
        }
        assert_eq!(hub.len(), 4, "ring never grows past capacity");
        assert_eq!(hub.dropped(), 6);
        assert_eq!(hub.steps(), 10);
        // latest + maxima stay fresh across drops
        assert_eq!(hub.latest().unwrap().step, 9);
        assert_eq!(hub.max_slab_high_water_bytes(), 1009);
        assert_eq!(hub.max_host_resident_bytes(), 90);
    }

    #[test]
    fn ewma_smooths_step_time() {
        let hub = MetricsHub::new();
        assert_eq!(hub.ewma_step_secs(), None);
        hub.record_step(StepSample { step_secs: 0.010, ..StepSample::default() });
        assert!((hub.ewma_step_secs().unwrap() - 0.010).abs() < 1e-12);
        hub.record_step(StepSample { step_secs: 0.020, ..StepSample::default() });
        let e = hub.ewma_step_secs().unwrap();
        assert!((e - 0.011).abs() < 1e-12, "0.9*0.010 + 0.1*0.020, got {e}");
    }

    #[test]
    fn readiness_latches_watchdog_and_tracks_degradation() {
        let hub = MetricsHub::new();
        assert!(hub.is_ready());
        hub.note_degrade_event(3);
        assert!(!hub.is_ready());
        assert_eq!(hub.degrade_events(), 1);
        assert_eq!(hub.degrade_rungs(), 3);
        hub.set_degraded(false);
        assert!(hub.is_ready(), "degradation clears when a healthy plan lands");
        hub.set_watchdog_fired();
        hub.set_degraded(false);
        assert!(!hub.is_ready(), "a fired watchdog never clears");
    }

    #[test]
    fn exposition_contains_every_series_and_parses() {
        let hub = MetricsHub::new();
        hub.record_step(sample(1));
        let text = hub.prometheus_text();
        for name in [
            "optorch_up",
            "optorch_ready",
            "optorch_arena_slab_high_water_bytes",
            "optorch_arena_scratch_used_bytes",
            "optorch_arena_scratch_high_water_bytes",
            "optorch_host_resident_bytes",
            "optorch_link_retry_backlog",
            "optorch_loader_queue_depth",
            "optorch_degrade_rung",
            "optorch_step_time_ewma_seconds",
            "optorch_steps_total",
            "optorch_samples_dropped_total",
            "optorch_degrade_events_total",
            "optorch_degrade_rungs_total",
        ] {
            assert!(text.contains(&format!("# TYPE {name} ")), "missing {name}\n{text}");
            assert!(
                text.lines().any(|l| l.starts_with(&format!("{name} "))),
                "no sample line for {name}\n{text}"
            );
        }
        // every non-comment line is `name value` with a numeric value
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut parts = line.split(' ');
            let name = parts.next().unwrap();
            assert!(name.starts_with("optorch_"), "{line}");
            let v = parts.next().expect("value");
            assert!(v.parse::<f64>().is_ok(), "unparseable value in {line}");
            assert_eq!(parts.next(), None, "trailing tokens in {line}");
        }
    }

    #[test]
    fn serve_series_gated_on_serve_mode() {
        let hub = MetricsHub::new();
        assert!(
            !hub.prometheus_text().contains("optorch_serve_"),
            "serve series must be absent outside serve mode"
        );
        hub.enable_serve_mode(8);
        hub.set_queue_depth(3);
        hub.note_admitted();
        hub.note_admitted();
        hub.record_batch(2);
        let text = hub.prometheus_text();
        for name in [
            "optorch_serve_queue_depth",
            "optorch_serve_shed_rate_window",
            "optorch_serve_admitted_total",
            "optorch_serve_shed_total",
            "optorch_serve_batches_total",
        ] {
            assert!(text.contains(&format!("# TYPE {name} ")), "missing {name}\n{text}");
        }
        assert!(text.contains("optorch_serve_queue_depth 3"), "{text}");
        assert!(text.contains("optorch_serve_admitted_total 2"), "{text}");
        assert!(
            text.contains("optorch_serve_batch_size{quantile=\"0.5\"}"),
            "batch-size quantiles render labeled\n{text}"
        );
    }

    #[test]
    fn shed_rate_window_drives_readiness() {
        let hub = MetricsHub::new();
        // outside serve mode sheds never affect readiness
        assert!(hub.is_ready());
        hub.enable_serve_mode(4);
        assert!(hub.is_ready(), "empty window is ready");
        hub.note_shed();
        assert!(!hub.is_ready(), "nonzero windowed shed rate → 503");
        assert_eq!(hub.shed(), 1);
        // the shed ages out of the 4-slot window after 4 admits
        for _ in 0..4 {
            hub.note_admitted();
        }
        assert_eq!(hub.shed_rate_window(), 0.0);
        assert!(hub.is_ready(), "shed aged out of the window");
    }

    #[test]
    fn phase_gauges_render_labeled_quantiles() {
        let hub = MetricsHub::new();
        assert!(!hub.prometheus_text().contains("optorch_phase_seconds"));
        hub.update_phase_stats(&[PhaseStat {
            name: "h2d copy".to_string(),
            count: 10,
            p50_secs: 0.001,
            p95_secs: 0.002,
            p99_secs: 0.004,
        }]);
        let text = hub.prometheus_text();
        assert!(text.contains("# TYPE optorch_phase_seconds gauge"), "{text}");
        assert!(
            text.contains("optorch_phase_seconds{phase=\"h2d_copy\",quantile=\"0.5\"} 0.001"),
            "space in phase name sanitized; p50 rendered\n{text}"
        );
        assert!(
            text.contains("optorch_phase_seconds{phase=\"h2d_copy\",quantile=\"0.99\"} 0.004"),
            "{text}"
        );
        // label values carry no spaces, so the `name value` line grammar
        // of exposition_contains_every_series_and_parses still holds
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split(' ').count(), 2, "{line}");
        }
    }

    #[test]
    fn csv_row_matches_header_arity() {
        let header_cols = StepSample::csv_header().split(',').count();
        let row = sample(7).to_csv_row();
        assert_eq!(row.split(',').count(), header_cols);
        assert!(row.starts_with("7,1007,70,64,128,1,2,0,0.010000"), "{row}");
    }
}
