//! Live observability: metrics hub, health/metrics HTTP endpoint, and
//! memory-watermark telemetry.
//!
//! PR 8's tracing layer closed the *time* loop — `DriftReport` compares
//! the planner's predicted step seconds against observed train-step
//! spans. This module closes the *memory* loop and makes a running
//! trainer scrape-able:
//!
//! - [`MetricsHub`] — typed gauge/counter series sampled once per train
//!   step into a fixed-capacity ring buffer. Same hot-path contract as
//!   `trace::event`: no allocation while recording; a full ring drops
//!   the sample and counts it instead of growing.
//! - [`ObsServer`] — a dependency-free blocking HTTP listener
//!   (`std::net::TcpListener`, one thread) serving Prometheus
//!   text-exposition `/metrics`, `/healthz` (liveness) and `/readyz`
//!   (503 while the `run_degraded` ladder is active or the loader
//!   watchdog has fired). Enabled via `train --metrics_addr`.
//! - [`MemTimeline`] / [`MemWatermarkReport`] — the memory twin of the
//!   time `DriftReport`: the facade's predicted peaks (DP peak, packed
//!   slab total, spilled host floor) versus the per-step high-water
//!   marks replayed from the resident lifetimes plus the engine's
//!   observed host residency. Surfaced in `TrainReport`, as a
//!   `train --memlog out.csv` per-step timeline, and offline via
//!   `plan --memdrift FILE`.

mod http;
mod hub;
mod watermark;

pub use http::ObsServer;
pub use hub::{MetricsHub, StepSample};
pub(crate) use watermark::memlog_csv;
pub use watermark::{MemTimeline, MemWatermarkReport, MemlogObserved};

use std::sync::Arc;

/// Bind an [`ObsServer`] over `hub` when `metrics_addr` is set.
///
/// Shared by the trainer and the serve loop so both expose the same
/// `/metrics` + `/healthz` + `/readyz` listener; returns `Ok(None)` when
/// no address was requested and propagates bind errors so a busy port
/// fails loudly instead of silently dropping observability.
pub fn spawn_obs_server(
    metrics_addr: Option<&str>,
    hub: &Arc<MetricsHub>,
) -> std::io::Result<Option<ObsServer>> {
    match metrics_addr {
        Some(addr) => ObsServer::bind(addr, Arc::clone(hub)).map(Some),
        None => Ok(None),
    }
}
