//! Memory-watermark telemetry: predicted peaks versus observed
//! high-water marks — the memory twin of the time
//! [`DriftReport`](crate::trace::DriftReport).
//!
//! The facade predicts three watermarks: the DP simulated peak
//! (`plan.peak_bytes`), the packed device total (`base + slab`, what the
//! runtime actually reserves) and — under spilling — the host-resident
//! floor. [`MemTimeline`] replays the staged resident lifetimes into a
//! per-schedule-step live-bytes series once at plan time; every train
//! step then *observes* the slab high-water mark from that series (the
//! schedule is deterministic per step) alongside the offload engine's
//! measured host residency. [`MemWatermarkReport`] folds the run into
//! one predicted-vs-observed line for `TrainReport`, and
//! [`MemlogObserved`] reads a `--memlog` CSV back for offline
//! `plan --memdrift` replay.

use crate::memory::arena::Lifetimes;
use crate::memory::outcome::PlanOutcome;
use crate::util::bench::fmt_bytes;
use crate::util::json::{n, obj, Json};

use super::StepSample;

/// Predicted watermarks plus the per-schedule-step live-bytes series of
/// one plan — extracted from a [`PlanOutcome`] before the trainer drops
/// it, cheap to query every step.
#[derive(Clone, Debug)]
pub struct MemTimeline {
    /// Live resident bytes at each schedule step (delta-sweep over the
    /// staged lifetimes; length = schedule steps).
    live_bytes: Vec<u64>,
    /// Static (params + momentum + input) bytes outside the slab.
    base_bytes: u64,
    /// DP simulated peak of the chosen plan.
    predicted_peak_bytes: u64,
    /// Packed device total (`base + slab`) the runtime reserves.
    predicted_packed_bytes: u64,
    /// Peak host bytes the spill composition predicts; `None` when the
    /// plan keeps everything device-resident.
    predicted_host_peak_bytes: Option<u64>,
}

impl MemTimeline {
    /// Extract the timeline from a planning outcome. `None` when the run
    /// staged no lifetimes (plan-only paths without an arena).
    pub fn from_outcome(outcome: &PlanOutcome) -> Option<MemTimeline> {
        let lifetimes = outcome.lifetimes()?;
        let predicted_host_peak_bytes = outcome
            .spill
            .as_ref()
            .filter(|s| !s.steps.is_empty())
            .map(|s| s.host_peak_bytes);
        Some(MemTimeline {
            live_bytes: live_series(lifetimes),
            base_bytes: lifetimes.base_bytes,
            predicted_peak_bytes: outcome.plan.peak_bytes,
            predicted_packed_bytes: outcome.device_peak_packed(),
            predicted_host_peak_bytes,
        })
    }

    /// Observed slab high-water mark: max concurrent live bytes over the
    /// schedule (what a per-step probe of the arena would report).
    pub fn slab_high_water_bytes(&self) -> u64 {
        self.live_bytes.iter().copied().max().unwrap_or(0)
    }

    /// Observed device peak: static base plus the slab high-water mark.
    /// For non-spill plans this equals the DP predicted peak exactly
    /// (the arena's `base + max live == peak` invariant).
    pub fn observed_peak_bytes(&self) -> u64 {
        self.base_bytes + self.slab_high_water_bytes()
    }

    pub fn base_bytes(&self) -> u64 {
        self.base_bytes
    }

    pub fn predicted_peak_bytes(&self) -> u64 {
        self.predicted_peak_bytes
    }

    pub fn predicted_packed_bytes(&self) -> u64 {
        self.predicted_packed_bytes
    }

    pub fn predicted_host_peak_bytes(&self) -> Option<u64> {
        self.predicted_host_peak_bytes
    }

    /// Live bytes at schedule step `i` (0 past the end).
    pub fn live_at(&self, i: usize) -> u64 {
        self.live_bytes.get(i).copied().unwrap_or(0)
    }

    /// Number of schedule steps in the series.
    pub fn len(&self) -> usize {
        self.live_bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.live_bytes.is_empty()
    }
}

/// Per-schedule-step live bytes: the same delta sweep as
/// [`Lifetimes::max_live_bytes`] with the prefix sums kept.
fn live_series(lt: &Lifetimes) -> Vec<u64> {
    let mut delta = vec![0i128; lt.steps + 1];
    for t in &lt.tensors {
        delta[t.start] += t.bytes as i128;
        delta[t.end] -= t.bytes as i128;
    }
    let mut live = 0i128;
    let mut series = Vec::with_capacity(lt.steps);
    for d in delta.iter().take(lt.steps) {
        live += *d;
        series.push(live as u64);
    }
    series
}

/// Predicted-vs-observed memory watermarks of one run.
#[derive(Clone, Debug, PartialEq)]
pub struct MemWatermarkReport {
    /// DP simulated peak of the chosen plan.
    pub predicted_peak_bytes: u64,
    /// Packed device total (`base + slab`) the runtime reserves.
    pub predicted_packed_bytes: u64,
    /// Predicted peak host bytes; `None` for non-spill plans.
    pub predicted_host_peak_bytes: Option<u64>,
    /// Observed device peak (`base + slab high-water`).
    pub observed_peak_bytes: u64,
    /// Observed slab high-water mark (max concurrent live bytes).
    pub observed_slab_high_water_bytes: u64,
    /// Observed peak host-resident bytes (0 when nothing spilled).
    pub observed_host_peak_bytes: u64,
    /// Train steps the observation covers.
    pub steps: u64,
}

impl MemWatermarkReport {
    /// Fold a run's observations against the plan's timeline. `None`
    /// when no step completed (nothing was observed).
    pub fn from_observed(
        timeline: &MemTimeline,
        observed_host_peak_bytes: u64,
        steps: u64,
    ) -> Option<MemWatermarkReport> {
        if steps == 0 {
            return None;
        }
        Some(MemWatermarkReport {
            predicted_peak_bytes: timeline.predicted_peak_bytes,
            predicted_packed_bytes: timeline.predicted_packed_bytes,
            predicted_host_peak_bytes: timeline.predicted_host_peak_bytes,
            observed_peak_bytes: timeline.observed_peak_bytes(),
            observed_slab_high_water_bytes: timeline.slab_high_water_bytes(),
            observed_host_peak_bytes,
            steps,
        })
    }

    /// Observed-vs-predicted relative error against the DP peak, in
    /// percent (negative = observed under prediction).
    pub fn rel_err_pct(&self) -> f64 {
        if self.predicted_peak_bytes == 0 {
            return 0.0;
        }
        (self.observed_peak_bytes as f64 - self.predicted_peak_bytes as f64)
            / self.predicted_peak_bytes as f64
            * 100.0
    }

    /// One markdown line, the memory twin of the drift line.
    pub fn to_markdown_line(&self) -> String {
        let mut line = format!(
            "mem-watermark: predicted peak {} (packed {}) vs observed peak {} ({:+.1}%); \
             slab high-water {}",
            fmt_bytes(self.predicted_peak_bytes),
            fmt_bytes(self.predicted_packed_bytes),
            fmt_bytes(self.observed_peak_bytes),
            self.rel_err_pct(),
            fmt_bytes(self.observed_slab_high_water_bytes),
        );
        match self.predicted_host_peak_bytes {
            Some(p) => line.push_str(&format!(
                ", host resident {} of {} predicted",
                fmt_bytes(self.observed_host_peak_bytes),
                fmt_bytes(p),
            )),
            None => line.push_str(", no spill"),
        }
        line.push_str(&format!(" over {} steps", self.steps));
        line
    }

    /// Stable JSON rendering (absent host prediction renders as `null`).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("predicted_peak_bytes", n(self.predicted_peak_bytes as f64)),
            ("predicted_packed_bytes", n(self.predicted_packed_bytes as f64)),
            (
                "predicted_host_peak_bytes",
                self.predicted_host_peak_bytes.map(|v| n(v as f64)).unwrap_or(Json::Null),
            ),
            ("observed_peak_bytes", n(self.observed_peak_bytes as f64)),
            ("observed_slab_high_water_bytes", n(self.observed_slab_high_water_bytes as f64)),
            ("observed_host_peak_bytes", n(self.observed_host_peak_bytes as f64)),
            ("rel_err_pct", n(self.rel_err_pct())),
            ("steps", n(self.steps as f64)),
        ])
    }
}

/// Observed watermarks read back from a `--memlog` CSV — the offline
/// half of `plan --memdrift FILE`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemlogObserved {
    /// Max `slab_high_water_bytes` across rows.
    pub slab_high_water_bytes: u64,
    /// Max `host_resident_bytes` across rows.
    pub host_peak_bytes: u64,
    /// Number of data rows (train steps logged).
    pub steps: u64,
}

impl MemlogObserved {
    /// Parse a `--memlog` export. Columns are located by header name, so
    /// the file survives column reordering; rows that fail to parse are
    /// an error (a truncated log should not silently under-report).
    pub fn parse_csv(text: &str) -> Result<MemlogObserved, String> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = lines.next().ok_or("memlog: empty file")?;
        let cols: Vec<&str> = header.split(',').map(str::trim).collect();
        let col = |name: &str| {
            cols.iter()
                .position(|c| *c == name)
                .ok_or_else(|| format!("memlog: missing column '{name}' in header '{header}'"))
        };
        let slab_idx = col("slab_high_water_bytes")?;
        let host_idx = col("host_resident_bytes")?;
        let mut observed =
            MemlogObserved { slab_high_water_bytes: 0, host_peak_bytes: 0, steps: 0 };
        for (i, line) in lines.enumerate() {
            let cells: Vec<&str> = line.split(',').collect();
            let cell = |idx: usize| -> Result<u64, String> {
                cells
                    .get(idx)
                    .and_then(|c| c.trim().parse::<u64>().ok())
                    .ok_or_else(|| format!("memlog: bad row {} '{line}'", i + 2))
            };
            observed.slab_high_water_bytes = observed.slab_high_water_bytes.max(cell(slab_idx)?);
            observed.host_peak_bytes = observed.host_peak_bytes.max(cell(host_idx)?);
            observed.steps += 1;
        }
        if observed.steps == 0 {
            return Err("memlog: no data rows".to_string());
        }
        Ok(observed)
    }

    /// Build the drift report against a freshly planned timeline, as if
    /// the logged run had just finished.
    pub fn against(&self, timeline: &MemTimeline) -> Option<MemWatermarkReport> {
        if self.steps == 0 {
            return None;
        }
        Some(MemWatermarkReport {
            predicted_peak_bytes: timeline.predicted_peak_bytes(),
            predicted_packed_bytes: timeline.predicted_packed_bytes(),
            predicted_host_peak_bytes: timeline.predicted_host_peak_bytes(),
            observed_peak_bytes: timeline.base_bytes() + self.slab_high_water_bytes,
            observed_slab_high_water_bytes: self.slab_high_water_bytes,
            observed_host_peak_bytes: self.host_peak_bytes,
            steps: self.steps,
        })
    }
}

/// Render a full `--memlog` CSV from recorded samples.
pub(crate) fn memlog_csv(samples: &[StepSample]) -> String {
    let mut out = String::with_capacity(64 * (samples.len() + 1));
    out.push_str(StepSample::csv_header());
    out.push('\n');
    for s in samples {
        out.push_str(&s.to_csv_row());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Pipeline;
    use crate::memory::pipeline::PlanRequest;

    fn try_outcome(budget: Option<u64>) -> Option<PlanOutcome> {
        let mut req = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .pipeline(Pipeline::parse("ed+sc").expect("pipeline"))
            .batch(8);
        if let Some(b) = budget {
            req = req.memory_budget(b).spill(true);
        }
        req.run().ok()
    }

    fn outcome(budget: Option<u64>) -> PlanOutcome {
        try_outcome(budget).expect("plan")
    }

    #[test]
    fn non_spill_observed_peak_equals_dp_peak() {
        let out = outcome(None);
        let tl = MemTimeline::from_outcome(&out).expect("timeline");
        assert_eq!(tl.observed_peak_bytes(), out.plan.peak_bytes);
        assert!(tl.observed_peak_bytes() <= out.device_peak_packed());
        // the series actually hits the max (equality on ≥ 1 step)
        let hw = tl.slab_high_water_bytes();
        assert!((0..tl.len()).any(|i| tl.live_at(i) == hw));
    }

    #[test]
    fn spill_observed_stays_under_packed_total() {
        let base = outcome(None);
        // Probe downward for a budget the spill composition can still
        // meet (the exact floor depends on the arch).
        let packed = base.device_peak_packed();
        let out = [95u64, 90, 80, 70]
            .iter()
            .find_map(|pct| try_outcome(Some(packed * pct / 100)))
            .unwrap_or(base);
        let tl = MemTimeline::from_outcome(&out).expect("timeline");
        assert!(tl.observed_peak_bytes() <= out.device_peak_packed());
        if out.is_spill() {
            assert!(tl.predicted_host_peak_bytes().is_some());
        }
    }

    #[test]
    fn report_line_and_json_round_out() {
        let out = outcome(None);
        let tl = MemTimeline::from_outcome(&out).expect("timeline");
        assert!(MemWatermarkReport::from_observed(&tl, 0, 0).is_none());
        let rep = MemWatermarkReport::from_observed(&tl, 0, 24).expect("report");
        assert_eq!(rep.steps, 24);
        assert!((rep.rel_err_pct()).abs() < 1e-9, "non-spill is exact");
        let line = rep.to_markdown_line();
        assert!(line.starts_with("mem-watermark: predicted peak "), "{line}");
        assert!(line.contains("no spill"), "{line}");
        assert!(line.ends_with("over 24 steps"), "{line}");
        let json = rep.to_json().to_string();
        assert!(json.contains("\"predicted_host_peak_bytes\":null"), "{json}");
        assert!(json.contains("\"steps\":24"), "{json}");
    }

    #[test]
    fn memlog_roundtrip_recovers_watermarks() {
        let samples: Vec<StepSample> = (0..5)
            .map(|i| StepSample {
                step: i,
                slab_high_water_bytes: 100 + i,
                host_resident_bytes: 7 * i,
                step_secs: 0.001,
                ..Default::default()
            })
            .collect();
        let csv = memlog_csv(&samples);
        assert!(csv.starts_with("step,slab_high_water_bytes,host_resident_bytes,"));
        assert_eq!(csv.lines().count(), 6);
        let obs = MemlogObserved::parse_csv(&csv).expect("parse");
        assert_eq!(obs.steps, 5);
        assert_eq!(obs.slab_high_water_bytes, 104);
        assert_eq!(obs.host_peak_bytes, 28);
    }

    #[test]
    fn memlog_parse_rejects_garbage() {
        assert!(MemlogObserved::parse_csv("").is_err());
        assert!(MemlogObserved::parse_csv("a,b,c\n1,2,3\n").is_err(), "missing columns");
        let header = StepSample::csv_header();
        assert!(
            MemlogObserved::parse_csv(&format!("{header}\n")).is_err(),
            "header but no rows"
        );
        assert!(
            MemlogObserved::parse_csv(&format!("{header}\n1,x,0,0,0,0,0,0,0.1\n")).is_err(),
            "unparseable cell"
        );
    }

    #[test]
    fn memlog_observed_against_fresh_plan() {
        let out = outcome(None);
        let tl = MemTimeline::from_outcome(&out).expect("timeline");
        let obs = MemlogObserved {
            slab_high_water_bytes: tl.slab_high_water_bytes(),
            host_peak_bytes: 0,
            steps: 12,
        };
        let rep = obs.against(&tl).expect("report");
        assert_eq!(rep.observed_peak_bytes, out.plan.peak_bytes);
        assert_eq!(rep.steps, 12);
    }
}
