//! Executable loading + train/eval/init execution over PJRT.

use crate::data::encode::EncodedBatch;
use crate::data::loader::BatchPayload;
use crate::memory::arena::ArenaAllocator;
use crate::memory::offload::{OffloadEngine, OffloadStats, SpillPlan};
use crate::runtime::manifest::{BatchKind, Manifest, ManifestEntry};
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

/// Shared PJRT client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>,
}

/// Training state: one `Literal` per manifest state tensor
/// (params ⊎ optimizer momentum), shuttled through each step.
pub struct TrainState {
    pub tensors: Vec<xla::Literal>,
}

impl TrainState {
    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total bytes held.
    pub fn bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.size_bytes()).sum()
    }
}

/// Output of one train/eval step.
#[derive(Clone, Copy, Debug)]
pub struct StepOutput {
    pub loss: f32,
    /// Correct predictions in the batch.
    pub correct: u32,
    pub batch_size: u32,
}

impl StepOutput {
    pub fn accuracy(&self) -> f64 {
        if self.batch_size == 0 {
            0.0
        } else {
            self.correct as f64 / self.batch_size as f64
        }
    }
}

/// A (model, pipeline)'s compiled executables.
pub struct LoadedModel {
    pub entry: ManifestEntry,
    /// Per-step marshaling arena: one slab sized by
    /// [`ManifestEntry::step_scratch_bytes`], recycled every step, so
    /// steady-state steps stage batch/label buffers without heap allocation.
    scratch: RefCell<ArenaAllocator>,
    /// Host-spill engine: replays the trainer's [`SpillPlan`] transfer
    /// schedule (recycled host buffers + counters) once per train step.
    /// `None` until [`LoadedModel::configure_offload`] installs a plan.
    offload: RefCell<Option<OffloadEngine>>,
    train: std::rc::Rc<xla::PjRtLoadedExecutable>,
    eval: std::rc::Rc<xla::PjRtLoadedExecutable>,
    init: std::rc::Rc<xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over `artifacts_dir`.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn compile(&mut self, file: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.get(file) {
            return Ok(exe.clone());
        }
        let path = self.manifest.hlo_path(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        let rc = std::rc::Rc::new(exe);
        self.cache.insert(file.to_string(), rc.clone());
        Ok(rc)
    }

    /// Load (and compile) a (model, pipeline)'s artifacts.
    pub fn load(&mut self, model: &str, pipeline: &str) -> Result<LoadedModel> {
        let entry = self
            .manifest
            .find(model, pipeline)
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for model='{model}' pipeline='{pipeline}' \
                     (available: {:?}) — run `make artifacts`",
                    self.manifest.models()
                )
            })?
            .clone();
        Ok(LoadedModel {
            train: self.compile(&entry.train_hlo)?,
            eval: self.compile(&entry.eval_hlo)?,
            init: self.compile(&entry.init_hlo)?,
            scratch: RefCell::new(ArenaAllocator::new(entry.step_scratch_bytes())),
            offload: RefCell::new(None),
            entry,
        })
    }
}

/// Build the batch literal from a loader payload, validating against the
/// manifest spec. Heap-staging convenience wrapper; the step hot path goes
/// through [`batch_literal_arena`].
pub fn batch_literal(entry: &ManifestEntry, payload: &BatchPayload) -> Result<xla::Literal> {
    batch_literal_arena(entry, payload, None)
}

/// [`batch_literal`] with encoded staging placed in `arena` when it fits
/// (falls back to the heap — counted by the arena — when it does not).
/// Raw payloads borrow the pixel slice directly and need no staging.
pub fn batch_literal_arena(
    entry: &ManifestEntry,
    payload: &BatchPayload,
    arena: Option<&mut ArenaAllocator>,
) -> Result<xla::Literal> {
    match (entry.batch_kind, payload) {
        (BatchKind::Raw, BatchPayload::Raw { data, n, .. }) => {
            if *n != entry.batch_size {
                bail!("batch has {n} images, artifact expects {}", entry.batch_size);
            }
            let dims: Vec<i64> = entry.batch_spec.shape.iter().map(|&d| d as i64).collect();
            Ok(xla::Literal::vec1(data).reshape(&dims)?)
        }
        (BatchKind::Encoded, BatchPayload::Encoded(groups)) => {
            encoded_literal(entry, groups, arena)
        }
        (kind, payload) => bail!(
            "payload kind mismatch: artifact wants {kind:?}, loader produced {}",
            match payload {
                BatchPayload::Raw { .. } => "raw",
                BatchPayload::Encoded(_) => "encoded",
            }
        ),
    }
}

fn encoded_literal(
    entry: &ManifestEntry,
    groups: &[EncodedBatch],
    arena: Option<&mut ArenaAllocator>,
) -> Result<xla::Literal> {
    if groups.len() != entry.groups {
        bail!(
            "encoded payload has {} groups, artifact expects {}",
            groups.len(),
            entry.groups
        );
    }
    let (h, w, c) = entry.input;
    let px = h * w * c;
    for g in groups {
        if g.words_f64.len() != px {
            bail!("group word count {} != {px}", g.words_f64.len());
        }
    }
    let dims: Vec<i64> = entry.batch_spec.shape.iter().map(|&d| d as i64).collect();
    let total = entry.groups * px;
    if px > 0 {
        if let Some(arena) = arena {
            if let Some(handle) = arena.alloc_f64(total) {
                let buf = arena.f64_mut(&handle);
                for (g, dst) in groups.iter().zip(buf.chunks_exact_mut(px)) {
                    dst.copy_from_slice(&g.words_f64);
                }
                return Ok(xla::Literal::vec1(buf).reshape(&dims)?);
            }
        }
    }
    let mut data = Vec::with_capacity(total);
    for g in groups {
        data.extend_from_slice(&g.words_f64);
    }
    Ok(xla::Literal::vec1(&data).reshape(&dims)?)
}

/// Labels literal `[B, K]` from the payload's soft labels.
/// Raw payloads borrow the label slice directly (§Perf: no per-step clone).
pub fn labels_literal(entry: &ManifestEntry, payload: &BatchPayload) -> Result<xla::Literal> {
    labels_literal_arena(entry, payload, None)
}

/// [`labels_literal`] with the encoded-payload gather staged in `arena`
/// when it fits (heap fallback otherwise, counted by the arena).
pub fn labels_literal_arena(
    entry: &ManifestEntry,
    payload: &BatchPayload,
    arena: Option<&mut ArenaAllocator>,
) -> Result<xla::Literal> {
    let want = entry.batch_size * entry.num_classes;
    let dims = [entry.batch_size as i64, entry.num_classes as i64];
    match payload {
        BatchPayload::Raw { labels, .. } => {
            if labels.len() != want {
                bail!("labels length {} != {want}", labels.len());
            }
            Ok(xla::Literal::vec1(labels).reshape(&dims)?)
        }
        BatchPayload::Encoded(groups) => {
            let have: usize = groups.iter().map(|g| g.labels.len()).sum();
            if have != want {
                bail!("labels length {have} != {want}");
            }
            if want > 0 {
                if let Some(arena) = arena {
                    if let Some(handle) = arena.alloc_f32(want) {
                        let buf = arena.f32_mut(&handle);
                        let mut off = 0;
                        for g in groups {
                            buf[off..off + g.labels.len()].copy_from_slice(&g.labels);
                            off += g.labels.len();
                        }
                        return Ok(xla::Literal::vec1(buf).reshape(&dims)?);
                    }
                }
            }
            let mut v = Vec::with_capacity(want);
            for g in groups {
                v.extend_from_slice(&g.labels);
            }
            Ok(xla::Literal::vec1(&v).reshape(&dims)?)
        }
    }
}

impl LoadedModel {
    /// The per-step marshaling arena (generation-tagged slab; see
    /// [`crate::memory::arena::alloc`]). Exposed for instrumentation —
    /// `fallback_allocs` flat across steps ⇒ staging ran inside the slab.
    pub fn scratch_arena(&self) -> &RefCell<ArenaAllocator> {
        &self.scratch
    }

    /// Install a host-spill plan: every subsequent train step replays its
    /// evict/prefetch schedule through the recycled host-buffer pool.
    pub fn configure_offload(&self, plan: &SpillPlan) {
        *self.offload.borrow_mut() = Some(OffloadEngine::new(plan));
    }

    /// Engine counters (`None` when no spill plan is installed).
    pub fn offload_stats(&self) -> Option<OffloadStats> {
        self.offload.borrow().as_ref().map(OffloadEngine::stats)
    }

    /// Host-pool resident high-water within the most recent replayed step
    /// (`None` when no spill plan is installed) — the per-step gauge
    /// behind `optorch_host_resident_bytes`.
    pub fn offload_step_host_peak(&self) -> Option<u64> {
        self.offload.borrow().as_ref().map(OffloadEngine::last_step_host_peak_bytes)
    }

    /// Inject (or clear) a deterministic link-fault model on the installed
    /// offload engine. No-op until [`LoadedModel::configure_offload`] ran.
    pub fn configure_link_faults(&self, link: Option<crate::memory::offload::LinkFaults>) {
        if let Some(engine) = self.offload.borrow_mut().as_mut() {
            engine.set_link_faults(link);
        }
    }

    /// Hand the installed offload engine a per-thread trace buffer: every
    /// replayed transfer and link fault lands on an `offload/link` track.
    /// No-op until [`LoadedModel::configure_offload`] ran; a replan
    /// replaces the engine, so callers re-install the tracer afterwards.
    pub fn configure_trace(&self, trace: crate::trace::ThreadTracer) {
        if let Some(engine) = self.offload.borrow_mut().as_mut() {
            engine.set_tracer(trace);
        }
    }

    /// Remove the installed host-spill plan (degradation abandoned
    /// spilling, e.g. the heap-fallback rung).
    pub fn clear_offload(&self) {
        *self.offload.borrow_mut() = None;
    }

    /// Initialize training state from a seed (runs the init artifact).
    pub fn init_state(&self, seed: u64) -> Result<TrainState> {
        let seed_lit = xla::Literal::vec1(&[(seed >> 32) as u32, seed as u32]).reshape(&[2])?;
        let result = self.init.execute::<xla::Literal>(&[seed_lit])?[0][0]
            .to_literal_sync()?;
        let tensors = result.to_tuple()?;
        if tensors.len() != self.entry.state.len() {
            bail!(
                "init returned {} tensors, manifest lists {}",
                tensors.len(),
                self.entry.state.len()
            );
        }
        Ok(TrainState { tensors })
    }

    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        state_tensors: &[xla::Literal],
        payload: &BatchPayload,
        lr: Option<f32>,
    ) -> Result<Vec<xla::Literal>> {
        // Stage batch/label marshaling through the step arena: one slab,
        // recycled here, zero steady-state heap allocation.
        let (batch, labels) = {
            let mut scratch = self.scratch.borrow_mut();
            scratch.begin_step();
            let batch = batch_literal_arena(&self.entry, payload, Some(&mut *scratch))?;
            let labels = labels_literal_arena(&self.entry, payload, Some(&mut *scratch))?;
            (batch, labels)
        };
        let lr_lit = lr.map(xla::Literal::scalar);
        let mut args: Vec<&xla::Literal> = state_tensors.iter().collect();
        args.push(&batch);
        args.push(&labels);
        if let Some(l) = &lr_lit {
            args.push(l);
        }
        let out = exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        Ok(out.to_tuple()?)
    }

    /// One optimizer step at the manifest's base learning rate.
    pub fn train_step(&self, state: &mut TrainState, payload: &BatchPayload) -> Result<StepOutput> {
        self.train_step_lr(state, payload, self.entry.lr as f32)
    }

    /// One optimizer step with an explicit learning rate (the artifact
    /// takes LR as a runtime scalar — schedules need no recompilation).
    pub fn train_step_lr(
        &self,
        state: &mut TrainState,
        payload: &BatchPayload,
        lr: f32,
    ) -> Result<StepOutput> {
        // Host-spill replay: evictions into recycled host buffers,
        // prefetch releases — the step's transfer schedule. A transfer
        // that exhausted its retry budget leaves the tensor
        // device-resident; the step proceeds degraded rather than dying.
        if let Some(engine) = self.offload.borrow_mut().as_mut() {
            if let Err(e) = engine.try_step() {
                crate::warn_!("{e}; continuing with the tensor device-resident");
            }
        }
        let mut out = self.run(&self.train, &state.tensors, payload, Some(lr))?;
        let s = self.entry.state.len();
        if out.len() != s + 2 {
            bail!("train step returned {} tensors, expected {}", out.len(), s + 2);
        }
        let correct = out.pop().unwrap().convert(xla::PrimitiveType::F32)?.get_first_element::<f32>()?;
        let loss = out.pop().unwrap().convert(xla::PrimitiveType::F32)?.get_first_element::<f32>()?;
        state.tensors = out;
        Ok(StepOutput {
            loss,
            correct: correct.round() as u32,
            batch_size: self.entry.batch_size as u32,
        })
    }

    /// Loss + correct-count on one batch without touching the state.
    /// The eval artifact takes only the parameter half of the state
    /// (momentum would be dead inputs — XLA strips them at compile).
    pub fn eval_step(&self, state: &TrainState, payload: &BatchPayload) -> Result<StepOutput> {
        let n_params = self.entry.state.len() / 2;
        let out = self.run(&self.eval, &state.tensors[..n_params], payload, None)?;
        if out.len() != 2 {
            bail!("eval step returned {} tensors, expected 2", out.len());
        }
        let loss = out[0].convert(xla::PrimitiveType::F32)?.get_first_element::<f32>()?;
        let correct = out[1].convert(xla::PrimitiveType::F32)?.get_first_element::<f32>()?;
        Ok(StepOutput {
            loss,
            correct: correct.round() as u32,
            batch_size: self.entry.batch_size as u32,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Dtype, TensorSpec};

    fn raw_entry() -> ManifestEntry {
        ManifestEntry {
            model: "m".into(),
            pipeline: "baseline".into(),
            input: (4, 4, 3),
            num_classes: 10,
            batch_size: 2,
            groups: 0,
            group_capacity: 0,
            batch_kind: BatchKind::Raw,
            batch_spec: TensorSpec {
                name: "batch".into(),
                shape: vec![2, 4, 4, 3],
                dtype: Dtype::F32,
            },
            labels_spec: TensorSpec {
                name: "labels".into(),
                shape: vec![2, 10],
                dtype: Dtype::F32,
            },
            state: vec![TensorSpec { name: "w".into(), shape: vec![3], dtype: Dtype::F32 }],
            train_hlo: "x".into(),
            eval_hlo: "x".into(),
            init_hlo: "x".into(),
            lr: 0.1,
            momentum: 0.9,
            loss_scale: 1.0,
            device_budget: None,
        }
    }

    #[test]
    fn batch_literal_raw_shape() {
        let e = raw_entry();
        let payload = BatchPayload::Raw {
            data: vec![0.5; 2 * 4 * 4 * 3],
            labels: vec![0.1; 20],
            n: 2,
        };
        let lit = batch_literal(&e, &payload).unwrap();
        assert_eq!(lit.element_count(), 96);
        let labels = labels_literal(&e, &payload).unwrap();
        assert_eq!(labels.element_count(), 20);
    }

    #[test]
    fn batch_literal_rejects_wrong_count() {
        let e = raw_entry();
        let payload = BatchPayload::Raw { data: vec![0.0; 48], labels: vec![0.0; 10], n: 1 };
        assert!(batch_literal(&e, &payload).is_err());
    }

    #[test]
    fn batch_literal_rejects_kind_mismatch() {
        let e = raw_entry();
        let payload = BatchPayload::Encoded(vec![]);
        assert!(batch_literal(&e, &payload).is_err());
    }

    fn encoded_entry() -> ManifestEntry {
        ManifestEntry {
            model: "m".into(),
            pipeline: "ed".into(),
            input: (2, 2, 3),
            num_classes: 3,
            batch_size: 2,
            groups: 2,
            group_capacity: 6,
            batch_kind: BatchKind::Encoded,
            batch_spec: TensorSpec {
                name: "batch".into(),
                shape: vec![2, 2, 2, 3],
                dtype: Dtype::F64,
            },
            labels_spec: TensorSpec {
                name: "labels".into(),
                shape: vec![2, 3],
                dtype: Dtype::F32,
            },
            state: vec![TensorSpec { name: "w".into(), shape: vec![3], dtype: Dtype::F32 }],
            train_hlo: "x".into(),
            eval_hlo: "x".into(),
            init_hlo: "x".into(),
            lr: 0.1,
            momentum: 0.9,
            loss_scale: 1.0,
            device_budget: None,
        }
    }

    fn encoded_group(px: usize, val: f64) -> EncodedBatch {
        use crate::data::encode::{Encoding, WordType};
        EncodedBatch {
            spec_encoding: Encoding::Base256,
            spec_word: WordType::F64,
            n: 1,
            h: 2,
            w: 2,
            c: 3,
            words_u64: vec![],
            words_f64: vec![val; px],
            offsets: vec![],
            labels: vec![0.5, 0.25, 0.25],
            num_classes: 3,
        }
    }

    #[test]
    fn encoded_staging_through_arena_matches_heap_path() {
        let e = encoded_entry();
        let px = 2 * 2 * 3;
        let payload =
            BatchPayload::Encoded(vec![encoded_group(px, 1.0), encoded_group(px, 2.0)]);
        let mut arena = ArenaAllocator::new(e.step_scratch_bytes());
        arena.begin_step();
        let batch = batch_literal_arena(&e, &payload, Some(&mut arena)).unwrap();
        let labels = labels_literal_arena(&e, &payload, Some(&mut arena)).unwrap();
        assert_eq!(arena.fallback_allocs(), 0, "staging must fit the sized slab");
        let batch_heap = batch_literal(&e, &payload).unwrap();
        let labels_heap = labels_literal(&e, &payload).unwrap();
        assert_eq!(batch.to_vec::<f64>().unwrap(), batch_heap.to_vec::<f64>().unwrap());
        assert_eq!(labels.to_vec::<f32>().unwrap(), labels_heap.to_vec::<f32>().unwrap());
        // recycling the slab keeps serving without growth
        arena.begin_step();
        let _ = batch_literal_arena(&e, &payload, Some(&mut arena)).unwrap();
        assert_eq!(arena.fallback_allocs(), 0);
        assert!(arena.high_water_bytes() <= arena.slab_bytes());
    }

    #[test]
    fn undersized_arena_falls_back_to_heap() {
        let e = encoded_entry();
        let px = 2 * 2 * 3;
        let payload =
            BatchPayload::Encoded(vec![encoded_group(px, 1.0), encoded_group(px, 2.0)]);
        let mut arena = ArenaAllocator::new(8); // far too small
        arena.begin_step();
        let batch = batch_literal_arena(&e, &payload, Some(&mut arena)).unwrap();
        assert!(arena.fallback_allocs() >= 1, "fallback must be counted");
        assert_eq!(batch.element_count(), 2 * px);
    }

    #[test]
    fn step_output_accuracy() {
        let s = StepOutput { loss: 1.0, correct: 12, batch_size: 16 };
        assert!((s.accuracy() - 0.75).abs() < 1e-9);
        let z = StepOutput { loss: 1.0, correct: 0, batch_size: 0 };
        assert_eq!(z.accuracy(), 0.0);
    }
}
