//! Executable loading + train/eval/init execution over PJRT.

use crate::data::encode::EncodedBatch;
use crate::data::loader::BatchPayload;
use crate::runtime::manifest::{BatchKind, Manifest, ManifestEntry};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Shared PJRT client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>,
}

/// Training state: one `Literal` per manifest state tensor
/// (params ⊎ optimizer momentum), shuttled through each step.
pub struct TrainState {
    pub tensors: Vec<xla::Literal>,
}

impl TrainState {
    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total bytes held.
    pub fn bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.size_bytes()).sum()
    }
}

/// Output of one train/eval step.
#[derive(Clone, Copy, Debug)]
pub struct StepOutput {
    pub loss: f32,
    /// Correct predictions in the batch.
    pub correct: u32,
    pub batch_size: u32,
}

impl StepOutput {
    pub fn accuracy(&self) -> f64 {
        if self.batch_size == 0 {
            0.0
        } else {
            self.correct as f64 / self.batch_size as f64
        }
    }
}

/// A (model, pipeline)'s compiled executables.
pub struct LoadedModel {
    pub entry: ManifestEntry,
    train: std::rc::Rc<xla::PjRtLoadedExecutable>,
    eval: std::rc::Rc<xla::PjRtLoadedExecutable>,
    init: std::rc::Rc<xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over `artifacts_dir`.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn compile(&mut self, file: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.get(file) {
            return Ok(exe.clone());
        }
        let path = self.manifest.hlo_path(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        let rc = std::rc::Rc::new(exe);
        self.cache.insert(file.to_string(), rc.clone());
        Ok(rc)
    }

    /// Load (and compile) a (model, pipeline)'s artifacts.
    pub fn load(&mut self, model: &str, pipeline: &str) -> Result<LoadedModel> {
        let entry = self
            .manifest
            .find(model, pipeline)
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for model='{model}' pipeline='{pipeline}' \
                     (available: {:?}) — run `make artifacts`",
                    self.manifest.models()
                )
            })?
            .clone();
        Ok(LoadedModel {
            train: self.compile(&entry.train_hlo)?,
            eval: self.compile(&entry.eval_hlo)?,
            init: self.compile(&entry.init_hlo)?,
            entry,
        })
    }
}

/// Build the batch literal from a loader payload, validating against the
/// manifest spec.
pub fn batch_literal(entry: &ManifestEntry, payload: &BatchPayload) -> Result<xla::Literal> {
    match (entry.batch_kind, payload) {
        (BatchKind::Raw, BatchPayload::Raw { data, n, .. }) => {
            if *n != entry.batch_size {
                bail!("batch has {n} images, artifact expects {}", entry.batch_size);
            }
            let dims: Vec<i64> = entry.batch_spec.shape.iter().map(|&d| d as i64).collect();
            Ok(xla::Literal::vec1(data).reshape(&dims)?)
        }
        (BatchKind::Encoded, BatchPayload::Encoded(groups)) => {
            encoded_literal(entry, groups)
        }
        (kind, payload) => bail!(
            "payload kind mismatch: artifact wants {kind:?}, loader produced {}",
            match payload {
                BatchPayload::Raw { .. } => "raw",
                BatchPayload::Encoded(_) => "encoded",
            }
        ),
    }
}

fn encoded_literal(entry: &ManifestEntry, groups: &[EncodedBatch]) -> Result<xla::Literal> {
    if groups.len() != entry.groups {
        bail!(
            "encoded payload has {} groups, artifact expects {}",
            groups.len(),
            entry.groups
        );
    }
    let (h, w, c) = entry.input;
    let px = h * w * c;
    let mut data = Vec::with_capacity(entry.groups * px);
    for g in groups {
        if g.words_f64.len() != px {
            bail!("group word count {} != {px}", g.words_f64.len());
        }
        data.extend_from_slice(&g.words_f64);
    }
    let dims: Vec<i64> = entry.batch_spec.shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(&data).reshape(&dims)?)
}

/// Labels literal `[B, K]` from the payload's soft labels.
/// Raw payloads borrow the label slice directly (§Perf: no per-step clone).
pub fn labels_literal(entry: &ManifestEntry, payload: &BatchPayload) -> Result<xla::Literal> {
    let want = entry.batch_size * entry.num_classes;
    let lit = match payload {
        BatchPayload::Raw { labels, .. } => {
            if labels.len() != want {
                bail!("labels length {} != {want}", labels.len());
            }
            xla::Literal::vec1(labels)
        }
        BatchPayload::Encoded(groups) => {
            let mut v = Vec::with_capacity(want);
            for g in groups {
                v.extend_from_slice(&g.labels);
            }
            if v.len() != want {
                bail!("labels length {} != {want}", v.len());
            }
            xla::Literal::vec1(&v)
        }
    };
    Ok(lit.reshape(&[entry.batch_size as i64, entry.num_classes as i64])?)
}

impl LoadedModel {
    /// Initialize training state from a seed (runs the init artifact).
    pub fn init_state(&self, seed: u64) -> Result<TrainState> {
        let seed_lit = xla::Literal::vec1(&[(seed >> 32) as u32, seed as u32]).reshape(&[2])?;
        let result = self.init.execute::<xla::Literal>(&[seed_lit])?[0][0]
            .to_literal_sync()?;
        let tensors = result.to_tuple()?;
        if tensors.len() != self.entry.state.len() {
            bail!(
                "init returned {} tensors, manifest lists {}",
                tensors.len(),
                self.entry.state.len()
            );
        }
        Ok(TrainState { tensors })
    }

    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        state_tensors: &[xla::Literal],
        payload: &BatchPayload,
        lr: Option<f32>,
    ) -> Result<Vec<xla::Literal>> {
        let batch = batch_literal(&self.entry, payload)?;
        let labels = labels_literal(&self.entry, payload)?;
        let lr_lit = lr.map(xla::Literal::scalar);
        let mut args: Vec<&xla::Literal> = state_tensors.iter().collect();
        args.push(&batch);
        args.push(&labels);
        if let Some(l) = &lr_lit {
            args.push(l);
        }
        let out = exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        Ok(out.to_tuple()?)
    }

    /// One optimizer step at the manifest's base learning rate.
    pub fn train_step(&self, state: &mut TrainState, payload: &BatchPayload) -> Result<StepOutput> {
        self.train_step_lr(state, payload, self.entry.lr as f32)
    }

    /// One optimizer step with an explicit learning rate (the artifact
    /// takes LR as a runtime scalar — schedules need no recompilation).
    pub fn train_step_lr(
        &self,
        state: &mut TrainState,
        payload: &BatchPayload,
        lr: f32,
    ) -> Result<StepOutput> {
        let mut out = self.run(&self.train, &state.tensors, payload, Some(lr))?;
        let s = self.entry.state.len();
        if out.len() != s + 2 {
            bail!("train step returned {} tensors, expected {}", out.len(), s + 2);
        }
        let correct = out.pop().unwrap().convert(xla::PrimitiveType::F32)?.get_first_element::<f32>()?;
        let loss = out.pop().unwrap().convert(xla::PrimitiveType::F32)?.get_first_element::<f32>()?;
        state.tensors = out;
        Ok(StepOutput {
            loss,
            correct: correct.round() as u32,
            batch_size: self.entry.batch_size as u32,
        })
    }

    /// Loss + correct-count on one batch without touching the state.
    /// The eval artifact takes only the parameter half of the state
    /// (momentum would be dead inputs — XLA strips them at compile).
    pub fn eval_step(&self, state: &TrainState, payload: &BatchPayload) -> Result<StepOutput> {
        let n_params = self.entry.state.len() / 2;
        let out = self.run(&self.eval, &state.tensors[..n_params], payload, None)?;
        if out.len() != 2 {
            bail!("eval step returned {} tensors, expected 2", out.len());
        }
        let loss = out[0].convert(xla::PrimitiveType::F32)?.get_first_element::<f32>()?;
        let correct = out[1].convert(xla::PrimitiveType::F32)?.get_first_element::<f32>()?;
        Ok(StepOutput {
            loss,
            correct: correct.round() as u32,
            batch_size: self.entry.batch_size as u32,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Dtype, TensorSpec};

    fn raw_entry() -> ManifestEntry {
        ManifestEntry {
            model: "m".into(),
            pipeline: "baseline".into(),
            input: (4, 4, 3),
            num_classes: 10,
            batch_size: 2,
            groups: 0,
            group_capacity: 0,
            batch_kind: BatchKind::Raw,
            batch_spec: TensorSpec {
                name: "batch".into(),
                shape: vec![2, 4, 4, 3],
                dtype: Dtype::F32,
            },
            labels_spec: TensorSpec {
                name: "labels".into(),
                shape: vec![2, 10],
                dtype: Dtype::F32,
            },
            state: vec![TensorSpec { name: "w".into(), shape: vec![3], dtype: Dtype::F32 }],
            train_hlo: "x".into(),
            eval_hlo: "x".into(),
            init_hlo: "x".into(),
            lr: 0.1,
            momentum: 0.9,
            loss_scale: 1.0,
        }
    }

    #[test]
    fn batch_literal_raw_shape() {
        let e = raw_entry();
        let payload = BatchPayload::Raw {
            data: vec![0.5; 2 * 4 * 4 * 3],
            labels: vec![0.1; 20],
            n: 2,
        };
        let lit = batch_literal(&e, &payload).unwrap();
        assert_eq!(lit.element_count(), 96);
        let labels = labels_literal(&e, &payload).unwrap();
        assert_eq!(labels.element_count(), 20);
    }

    #[test]
    fn batch_literal_rejects_wrong_count() {
        let e = raw_entry();
        let payload = BatchPayload::Raw { data: vec![0.0; 48], labels: vec![0.0; 10], n: 1 };
        assert!(batch_literal(&e, &payload).is_err());
    }

    #[test]
    fn batch_literal_rejects_kind_mismatch() {
        let e = raw_entry();
        let payload = BatchPayload::Encoded(vec![]);
        assert!(batch_literal(&e, &payload).is_err());
    }

    #[test]
    fn step_output_accuracy() {
        let s = StepOutput { loss: 1.0, correct: 12, batch_size: 16 };
        assert!((s.accuracy() - 0.75).abs() < 1e-9);
        let z = StepOutput { loss: 1.0, correct: 0, batch_size: 0 };
        assert_eq!(z.accuracy(), 0.0);
    }
}
