//! Artifact manifest: the contract between `python/compile/aot.py` (writer)
//! and the rust runtime (reader). See DESIGN.md §2.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Element type of a tensor crossing the PJRT boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F16,
    F32,
    F64,
    U32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype, String> {
        match s {
            "f16" | "float16" => Ok(Dtype::F16),
            "f32" | "float32" => Ok(Dtype::F32),
            "f64" | "float64" => Ok(Dtype::F64),
            "u32" | "uint32" => Ok(Dtype::U32),
            other => Err(format!("unknown dtype '{other}'")),
        }
    }

    pub fn bytes(self) -> usize {
        match self {
            Dtype::F16 => 2,
            Dtype::F32 | Dtype::U32 => 4,
            Dtype::F64 => 8,
        }
    }
}

/// Shape + dtype of one tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elems() * self.dtype.bytes()
    }

    fn from_json(j: &Json) -> Result<TensorSpec, String> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or("tensor missing name")?
            .to_string();
        let dtype = Dtype::parse(
            j.get("dtype").and_then(Json::as_str).ok_or("tensor missing dtype")?,
        )?;
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or("tensor missing shape")?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| format!("bad dim in {name}")))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// How the batch input is shipped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchKind {
    /// f32 `[B, H, W, C]`.
    Raw,
    /// Packed base-256 f64 words `[G, H, W, C]` (E-D pipelines).
    Encoded,
}

/// One (model, pipeline) artifact set.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub model: String,
    pub pipeline: String,
    pub input: (usize, usize, usize),
    pub num_classes: usize,
    pub batch_size: usize,
    /// Encoded-group count (E-D) and per-group capacity; 0 for raw.
    pub groups: usize,
    pub group_capacity: usize,
    pub batch_kind: BatchKind,
    pub batch_spec: TensorSpec,
    pub labels_spec: TensorSpec,
    pub state: Vec<TensorSpec>,
    pub train_hlo: String,
    pub eval_hlo: String,
    pub init_hlo: String,
    pub lr: f64,
    pub momentum: f64,
    pub loss_scale: f64,
    /// Optional device-memory budget (bytes) the artifact was compiled
    /// for. When present and the training config sets no explicit
    /// `memory_budget`, the trainer plans against it (S-C pipelines).
    pub device_budget: Option<u64>,
}

impl ManifestEntry {
    pub fn state_bytes(&self) -> usize {
        self.state.iter().map(TensorSpec::bytes).sum()
    }

    /// Per-step marshaling scratch (bytes) the runtime stages through the
    /// activation arena ([`crate::memory::arena`]): encoded batches rebuild
    /// the packed word tensor (`[G, H, W, C]` f64) and the label matrix
    /// (`[B, K]` f32) every step, raw batches borrow the loader payload
    /// directly and need no staging. Each buffer is rounded to the arena
    /// alignment so both fit one slab.
    pub fn step_scratch_bytes(&self) -> usize {
        match self.batch_kind {
            BatchKind::Raw => 0,
            BatchKind::Encoded => {
                let (h, w, c) = self.input;
                let px = h * w * c;
                let align8 = |b: usize| b.div_ceil(8) * 8;
                align8(self.groups * px * 8) + align8(self.batch_size * self.num_classes * 4)
            }
        }
    }

    fn from_json(j: &Json) -> Result<ManifestEntry, String> {
        let get_str = |k: &str| -> Result<String, String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(String::from)
                .ok_or(format!("entry missing '{k}'"))
        };
        let get_usize = |k: &str| -> Result<usize, String> {
            j.get(k).and_then(Json::as_usize).ok_or(format!("entry missing '{k}'"))
        };
        let get_f64 = |k: &str| -> Result<f64, String> {
            j.get(k).and_then(Json::as_f64).ok_or(format!("entry missing '{k}'"))
        };
        let input_arr = j
            .get("input")
            .and_then(Json::as_arr)
            .ok_or("entry missing 'input'")?;
        if input_arr.len() != 3 {
            return Err("'input' must be [h, w, c]".into());
        }
        let input = (
            input_arr[0].as_usize().ok_or("bad input dim")?,
            input_arr[1].as_usize().ok_or("bad input dim")?,
            input_arr[2].as_usize().ok_or("bad input dim")?,
        );
        let batch_kind = match get_str("batch_kind")?.as_str() {
            "raw" => BatchKind::Raw,
            "encoded" => BatchKind::Encoded,
            other => return Err(format!("unknown batch_kind '{other}'")),
        };
        let state = j
            .get("state")
            .and_then(Json::as_arr)
            .ok_or("entry missing 'state'")?
            .iter()
            .map(TensorSpec::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        if state.is_empty() {
            return Err("entry has empty state".into());
        }
        Ok(ManifestEntry {
            model: get_str("model")?,
            pipeline: get_str("pipeline")?,
            input,
            num_classes: get_usize("num_classes")?,
            batch_size: get_usize("batch_size")?,
            groups: get_usize("groups").unwrap_or(0),
            group_capacity: get_usize("group_capacity").unwrap_or(0),
            batch_kind,
            batch_spec: TensorSpec::from_json(j.get("batch").ok_or("entry missing 'batch'")?)?,
            labels_spec: TensorSpec::from_json(
                j.get("labels").ok_or("entry missing 'labels'")?,
            )?,
            state,
            train_hlo: get_str("train_hlo")?,
            eval_hlo: get_str("eval_hlo")?,
            init_hlo: get_str("init_hlo")?,
            lr: get_f64("lr")?,
            momentum: get_f64("momentum")?,
            loss_scale: get_f64("loss_scale").unwrap_or(1.0),
            device_budget: match j.get("device_budget") {
                None => None,
                // present ⇒ must parse: a silently dropped budget would
                // un-cap exactly the artifact that asked for one. Suffixed
                // strings ("512MiB") route through the memory facade's
                // shared byte parser, same as every other budget source.
                Some(Json::Str(text)) => Some(
                    crate::memory::pipeline::parse_bytes_field("device_budget", text)
                        .map_err(|e| e.to_string())?,
                ),
                Some(v) => Some(
                    v.as_usize()
                        .map(|b| b as u64)
                        .ok_or("bad 'device_budget' (want bytes or a suffixed string)")?,
                ),
            },
        })
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Parse manifest text (exposed for tests).
    pub fn from_text(dir: &Path, text: &str) -> Result<Manifest, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let version = j.get("version").and_then(Json::as_usize).unwrap_or(0);
        if version != 1 {
            return Err(format!("unsupported manifest version {version}"));
        }
        let entries = j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or("manifest missing 'entries'")?
            .iter()
            .map(ManifestEntry::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e} (run `make artifacts` first)", path.display()))?;
        Self::from_text(dir, &text)
    }

    /// Look up a (model, pipeline-name) entry.
    pub fn find(&self, model: &str, pipeline: &str) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .find(|e| e.model == model && e.pipeline == pipeline)
    }

    pub fn models(&self) -> Vec<String> {
        let mut v: Vec<String> = self.entries.iter().map(|e| e.model.clone()).collect();
        v.sort();
        v.dedup();
        v
    }

    /// Absolute path of an HLO file referenced by an entry.
    pub fn hlo_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> String {
        r#"{
            "version": 1,
            "entries": [{
                "model": "tiny_cnn", "pipeline": "baseline",
                "input": [32, 32, 3], "num_classes": 10, "batch_size": 16,
                "batch_kind": "raw",
                "batch": {"name": "batch", "shape": [16, 32, 32, 3], "dtype": "f32"},
                "labels": {"name": "labels", "shape": [16, 10], "dtype": "f32"},
                "state": [
                    {"name": "conv1/w", "shape": [3, 3, 3, 16], "dtype": "f32"},
                    {"name": "conv1/b", "shape": [16], "dtype": "f32"}
                ],
                "train_hlo": "tiny_cnn_baseline.train.hlo.txt",
                "eval_hlo": "tiny_cnn_baseline.eval.hlo.txt",
                "init_hlo": "tiny_cnn_baseline.init.hlo.txt",
                "lr": 0.05, "momentum": 0.9
            }]
        }"#
        .to_string()
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::from_text(Path::new("artifacts"), &sample()).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.find("tiny_cnn", "baseline").unwrap();
        assert_eq!(e.input, (32, 32, 3));
        assert_eq!(e.batch_kind, BatchKind::Raw);
        assert_eq!(e.state.len(), 2);
        assert_eq!(e.state[0].elems(), 3 * 3 * 3 * 16);
        assert_eq!(e.state_bytes(), (432 + 16) * 4);
        assert_eq!(e.loss_scale, 1.0); // default
        assert_eq!(e.device_budget, None); // absent in older manifests
        assert!(m.find("tiny_cnn", "ed").is_none());
        assert_eq!(m.models(), vec!["tiny_cnn"]);
        assert_eq!(
            m.hlo_path(&e.train_hlo),
            Path::new("artifacts/tiny_cnn_baseline.train.hlo.txt")
        );
    }

    #[test]
    fn rejects_bad_version() {
        let text = r#"{"version": 2, "entries": []}"#;
        assert!(Manifest::from_text(Path::new("a"), text).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        let text = r#"{"version": 1, "entries": [{"model": "m"}]}"#;
        let err = Manifest::from_text(Path::new("a"), text).unwrap_err();
        assert!(err.contains("missing"), "{err}");
    }

    #[test]
    fn rejects_empty_state() {
        let text = sample().replace(
            r#""state": [
                    {"name": "conv1/w", "shape": [3, 3, 3, 16], "dtype": "f32"},
                    {"name": "conv1/b", "shape": [16], "dtype": "f32"}
                ]"#,
            r#""state": []"#,
        );
        assert!(Manifest::from_text(Path::new("a"), &text).is_err());
    }

    #[test]
    fn step_scratch_bytes_by_kind() {
        let m = Manifest::from_text(Path::new("a"), &sample()).unwrap();
        let mut e = m.entries[0].clone();
        assert_eq!(e.step_scratch_bytes(), 0, "raw batches borrow the payload");
        e.batch_kind = BatchKind::Encoded;
        e.groups = 3;
        // 3 groups × 32·32·3 words × 8 B + 16×10 f32 labels (8-aligned)
        assert_eq!(e.step_scratch_bytes(), 3 * 32 * 32 * 3 * 8 + 16 * 10 * 4);
    }

    #[test]
    fn device_budget_parses_when_present() {
        let text = sample().replace("\"lr\": 0.05", "\"device_budget\": 786432, \"lr\": 0.05");
        let m = Manifest::from_text(Path::new("a"), &text).unwrap();
        assert_eq!(m.entries[0].device_budget, Some(786_432));
        // suffixed strings go through the shared facade parser
        let text = sample().replace("\"lr\": 0.05", "\"device_budget\": \"512MiB\", \"lr\": 0.05");
        let m = Manifest::from_text(Path::new("a"), &text).unwrap();
        assert_eq!(m.entries[0].device_budget, Some(512 * 1024 * 1024));
        // present but malformed must error (naming the field), not
        // silently un-cap the artifact
        let bad = sample().replace("\"lr\": 0.05", "\"device_budget\": \"lots\", \"lr\": 0.05");
        let err = Manifest::from_text(Path::new("a"), &bad).unwrap_err();
        assert!(err.contains("device_budget"), "{err}");
        let bad = sample().replace("\"lr\": 0.05", "\"device_budget\": true, \"lr\": 0.05");
        let err = Manifest::from_text(Path::new("a"), &bad).unwrap_err();
        assert!(err.contains("device_budget"), "{err}");
    }

    #[test]
    fn dtype_parsing() {
        assert_eq!(Dtype::parse("f16").unwrap(), Dtype::F16);
        assert_eq!(Dtype::parse("float64").unwrap(), Dtype::F64);
        assert!(Dtype::parse("int8").is_err());
        assert_eq!(Dtype::F16.bytes(), 2);
        assert_eq!(Dtype::F64.bytes(), 8);
    }
}
