//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, emitted
//! once by `python/compile/aot.py`) and executes them from the training
//! hot path. Python never runs here.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (tensor specs, file
//!   names, hyper-parameters) with the in-crate JSON parser. Always
//!   compiled (pure Rust).
//! * `exec` — compiles HLO text on the PJRT CPU client and drives the
//!   train/eval/init executables; training state lives as XLA `Literal`s
//!   between steps (the 0.1.6 `xla` crate returns tuple outputs as a
//!   single buffer, so state crosses the host boundary per step — see
//!   DESIGN.md §Perf for the measured cost). **Feature-gated**: only
//!   compiled with `--features pjrt`, which pulls in the `xla` dependency.
//! * `stub` — the default (no `pjrt` feature) stand-in exposing the same
//!   `Runtime`/`LoadedModel`/`TrainState`/`state_io` API; construction
//!   fails with a clear "built without the `pjrt` feature" error, so the
//!   data pipeline, simulator, planner and all their tests build and run
//!   in environments without a PJRT toolchain.

pub mod manifest;

#[cfg(feature = "pjrt")]
pub mod exec;
#[cfg(feature = "pjrt")]
pub mod state_io;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::state_io;

#[cfg(feature = "pjrt")]
pub use exec::{LoadedModel, Runtime, StepOutput, TrainState};
#[cfg(not(feature = "pjrt"))]
pub use stub::{LoadedModel, Runtime, StepOutput, TrainState};

pub use manifest::{BatchKind, Dtype, Manifest, ManifestEntry, TensorSpec};
