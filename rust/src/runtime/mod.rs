//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, emitted
//! once by `python/compile/aot.py`) and executes them from the training
//! hot path. Python never runs here.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (tensor specs, file
//!   names, hyper-parameters) with the in-crate JSON parser.
//! * [`exec`] — compiles HLO text on the PJRT CPU client and drives the
//!   train/eval/init executables; training state lives as XLA `Literal`s
//!   between steps (the 0.1.6 `xla` crate returns tuple outputs as a
//!   single buffer, so state crosses the host boundary per step — see
//!   DESIGN.md §Perf for the measured cost).

pub mod exec;
pub mod manifest;
pub mod state_io;

pub use exec::{LoadedModel, Runtime, StepOutput, TrainState};
pub use manifest::{BatchKind, Dtype, Manifest, ManifestEntry, TensorSpec};
