//! Training-state checkpointing to disk: save/resume runs across
//! processes. Tensors are stored as f32 (f16 state is widened on save and
//! re-narrowed on load — exact, since f16 ⊂ f32), with the manifest specs
//! validating shape and order on both sides.
//!
//! Format: magic, tensor count, then per tensor: name-len, name bytes,
//! elem count, f32 little-endian data — and, since `OPTSTAT2`, a trailing
//! CRC-32 of everything before it, so a checkpoint corrupted at rest is a
//! typed load error instead of silently wrong weights. `OPTSTAT1` files
//! (pre-checksum) still load, without integrity verification.

use crate::runtime::manifest::{Dtype, ManifestEntry};
use crate::runtime::TrainState;
use crate::util::crc::crc32;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"OPTSTAT2";
const LEGACY_MAGIC: &[u8; 8] = b"OPTSTAT1";

/// Serialize `state` (validated against `entry`) to `path`.
pub fn save(path: &Path, entry: &ManifestEntry, state: &TrainState) -> Result<()> {
    if state.tensors.len() != entry.state.len() {
        bail!(
            "state has {} tensors, manifest lists {}",
            state.tensors.len(),
            entry.state.len()
        );
    }
    // The format stores every length in a u32 field; a spec that cannot
    // be represented must be rejected up front (before any widening), or
    // the file would be silently unreadable.
    if state.tensors.len() > u32::MAX as usize {
        bail!("state has {} tensors, more than the u32 count field can hold", state.tensors.len());
    }
    for spec in &entry.state {
        if spec.name.len() > u32::MAX as usize {
            bail!(
                "tensor name of {} bytes overflows the u32 name-length field",
                spec.name.len()
            );
        }
        if spec.elems() > u32::MAX as usize {
            bail!(
                "{}: {} elems overflows the u32 element-count field \
                 (payload would be unreadable on load)",
                spec.name,
                spec.elems()
            );
        }
    }
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(state.tensors.len() as u32).to_le_bytes());
    for (tensor, spec) in state.tensors.iter().zip(&entry.state) {
        let widened = tensor
            .convert(xla::PrimitiveType::F32)
            .with_context(|| format!("widen {}", spec.name))?;
        let data: Vec<f32> = widened.to_vec()?;
        if data.len() != spec.elems() {
            bail!("{}: {} elems, spec says {}", spec.name, data.len(), spec.elems());
        }
        buf.extend_from_slice(&(spec.name.len() as u32).to_le_bytes());
        buf.extend_from_slice(spec.name.as_bytes());
        buf.extend_from_slice(&(data.len() as u32).to_le_bytes());
        for v in data {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());
    std::fs::File::create(path)?.write_all(&buf)?;
    Ok(())
}

fn take<'a>(b: &mut &'a [u8], n: usize, what: &str) -> Result<&'a [u8]> {
    if b.len() < n {
        bail!("truncated state file while reading {what}");
    }
    let (head, tail) = b.split_at(n);
    *b = tail;
    Ok(head)
}

/// Load a state checkpoint for `entry` from `path`.
pub fn load(path: &Path, entry: &ManifestEntry) -> Result<TrainState> {
    let mut raw = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?
        .read_to_end(&mut raw)?;
    let mut b: &[u8] = &raw;
    let magic = take(&mut b, 8, "magic")?;
    if magic == MAGIC {
        // Checksummed format: verify the trailing CRC-32 over everything
        // before it, then parse the payload between magic and checksum.
        if b.len() < 4 {
            bail!("{}: truncated state file (missing checksum)", path.display());
        }
        let (payload, tail) = raw.split_at(raw.len() - 4);
        let stored = u32::from_le_bytes(tail.try_into().unwrap());
        let computed = crc32(payload);
        if stored != computed {
            bail!(
                "{}: state checksum mismatch: stored {stored:#010x}, computed \
                 {computed:#010x} (file corrupt — re-save the checkpoint)",
                path.display()
            );
        }
        b = &payload[8..];
    } else if magic != LEGACY_MAGIC {
        bail!("{}: not an optorch state file", path.display());
    }
    let count = u32::from_le_bytes(take(&mut b, 4, "count")?.try_into().unwrap()) as usize;
    if count != entry.state.len() {
        bail!(
            "{}: {count} tensors, artifact for {}/{} expects {}",
            path.display(),
            entry.model,
            entry.pipeline,
            entry.state.len()
        );
    }
    let mut tensors = Vec::with_capacity(count);
    for spec in &entry.state {
        let name_len =
            u32::from_le_bytes(take(&mut b, 4, "name len")?.try_into().unwrap()) as usize;
        let name = std::str::from_utf8(take(&mut b, name_len, "name")?)
            .context("tensor name not utf-8")?;
        if name != spec.name {
            bail!("tensor order mismatch: file has '{name}', manifest expects '{}'", spec.name);
        }
        let elems =
            u32::from_le_bytes(take(&mut b, 4, "elem count")?.try_into().unwrap()) as usize;
        if elems != spec.elems() {
            bail!("{name}: {elems} elems, spec says {}", spec.elems());
        }
        let bytes = take(&mut b, elems * 4, "tensor data")?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let mut lit = xla::Literal::vec1(&data);
        if !dims.is_empty() {
            lit = lit.reshape(&dims)?;
        }
        if spec.dtype == Dtype::F16 {
            lit = lit.convert(xla::PrimitiveType::F16)?;
        }
        tensors.push(lit);
    }
    if !b.is_empty() {
        bail!("{}: trailing bytes after state", path.display());
    }
    Ok(TrainState { tensors })
}

#[cfg(test)]
mod tests {
    // Round-trip tests that need real literals live in
    // rust/tests/integration_runtime.rs (they require the PJRT artifacts);
    // header validation is testable here.
    use super::*;
    use crate::runtime::manifest::TensorSpec;

    fn entry() -> ManifestEntry {
        ManifestEntry {
            model: "m".into(),
            pipeline: "baseline".into(),
            input: (4, 4, 3),
            num_classes: 10,
            batch_size: 2,
            groups: 0,
            group_capacity: 0,
            batch_kind: crate::runtime::BatchKind::Raw,
            batch_spec: TensorSpec { name: "batch".into(), shape: vec![2, 4, 4, 3], dtype: Dtype::F32 },
            labels_spec: TensorSpec { name: "labels".into(), shape: vec![2, 10], dtype: Dtype::F32 },
            state: vec![TensorSpec { name: "w".into(), shape: vec![3], dtype: Dtype::F32 }],
            train_hlo: "x".into(),
            eval_hlo: "x".into(),
            init_hlo: "x".into(),
            lr: 0.1,
            momentum: 0.9,
            loss_scale: 1.0,
            device_budget: None,
        }
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        let dir = std::env::temp_dir().join(format!("optorch_sio_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.state");
        std::fs::write(&p, b"NOTMAGIC").unwrap();
        assert!(load(&p, &entry()).is_err());
        std::fs::write(&p, b"OPT").unwrap();
        assert!(load(&p, &entry()).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_elem_counts_that_overflow_the_u32_field() {
        // A spec whose element count cannot be stored in the u32 length
        // field must be rejected before the data-length comparison (the
        // tiny tensor would otherwise report a confusing mismatch).
        let dir = std::env::temp_dir().join(format!("optorch_sio3_{}", std::process::id()));
        let mut e = entry();
        e.state[0].shape = vec![1 << 17, 1 << 17]; // 2^34 elems > u32::MAX
        let state = TrainState { tensors: vec![xla::Literal::vec1(&[0.0f32; 3])] };
        let err = match save(&dir.join("of.state"), &e, &state) {
            Err(err) => err,
            Ok(()) => panic!("expected overflow rejection"),
        };
        assert!(err.to_string().contains("overflows the u32"), "{err}");
        assert!(!dir.join("of.state").exists(), "nothing must be written");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_load_roundtrip_property_covers_f16_widened_state() {
        use crate::runtime::manifest::TensorSpec;
        use crate::util::propcheck::check_with;
        let dir = std::env::temp_dir().join(format!("optorch_sio4_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.state");
        check_with(
            "state_io save→load roundtrips f32 and f16-widened tensors",
            24,
            0x510,
            |rng| {
                let count = 1 + rng.gen_range(3);
                let tensors: Vec<(Vec<usize>, Dtype, Vec<f32>)> = (0..count)
                    .map(|_| {
                        let shape = vec![1 + rng.gen_range(4), 1 + rng.gen_range(5)];
                        let dtype = if rng.gen_range(2) == 0 { Dtype::F32 } else { Dtype::F16 };
                        let elems = shape.iter().product::<usize>();
                        // f16-representable values so the widen/narrow
                        // cycle is exact under the real xla crate too
                        let data: Vec<f32> = (0..elems)
                            .map(|_| (rng.gen_range(512) as f32 - 256.0) / 8.0)
                            .collect();
                        (shape, dtype, data)
                    })
                    .collect();
                tensors
            },
            |tensors| {
                let mut e = entry();
                e.state = tensors
                    .iter()
                    .enumerate()
                    .map(|(i, (shape, dtype, _))| TensorSpec {
                        name: format!("t{i}"),
                        shape: shape.clone(),
                        dtype: *dtype,
                    })
                    .collect();
                let state = TrainState {
                    tensors: tensors
                        .iter()
                        .map(|(shape, dtype, data)| {
                            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                            let mut lit = xla::Literal::vec1(data).reshape(&dims).unwrap();
                            if *dtype == Dtype::F16 {
                                lit = lit.convert(xla::PrimitiveType::F16).unwrap();
                            }
                            lit
                        })
                        .collect(),
                };
                save(&path, &e, &state).map_err(|err| err.to_string())?;
                let restored = load(&path, &e).map_err(|err| err.to_string())?;
                for (i, (orig, back)) in state.tensors.iter().zip(&restored.tensors).enumerate() {
                    let a: Vec<f32> =
                        orig.convert(xla::PrimitiveType::F32).unwrap().to_vec().unwrap();
                    let b: Vec<f32> =
                        back.convert(xla::PrimitiveType::F32).unwrap().to_vec().unwrap();
                    if a != b {
                        return Err(format!("tensor {i} differs after roundtrip"));
                    }
                }
                Ok(())
            },
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_tensor_count_mismatch() {
        let dir = std::env::temp_dir().join(format!("optorch_sio2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("count.state");
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&5u32.to_le_bytes()); // entry expects 1
        let crc = crc32(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        std::fs::write(&p, &buf).unwrap();
        let err = match load(&p, &entry()) {
            Err(e) => e,
            Ok(_) => panic!("expected count mismatch"),
        };
        assert!(err.to_string().contains("expects 1"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn detects_corrupted_checkpoints() {
        let dir = std::env::temp_dir().join(format!("optorch_sio5_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("flip.state");
        let state = TrainState { tensors: vec![xla::Literal::vec1(&[1.0f32, 2.0, 3.0])] };
        let mut e = entry();
        e.state[0].shape = vec![3];
        save(&p, &e, &state).unwrap();
        load(&p, &e).unwrap();
        // flip one bit in the middle of the tensor data
        let mut raw = std::fs::read(&p).unwrap();
        let mid = raw.len() / 2;
        raw[mid] ^= 0x04;
        std::fs::write(&p, &raw).unwrap();
        let err = match load(&p, &e) {
            Err(err) => err,
            Ok(_) => panic!("expected checksum mismatch"),
        };
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
        // a truncated checksummed file is also typed, not a panic
        std::fs::write(&p, &raw[..9]).unwrap();
        assert!(load(&p, &e).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn accepts_legacy_unchecksummed_checkpoints() {
        let dir = std::env::temp_dir().join(format!("optorch_sio6_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("legacy.state");
        let state = TrainState { tensors: vec![xla::Literal::vec1(&[1.0f32, 2.0, 3.0])] };
        let mut e = entry();
        e.state[0].shape = vec![3];
        save(&p, &e, &state).unwrap();
        // rewrite as the pre-checksum format: legacy magic, no trailing CRC
        let raw = std::fs::read(&p).unwrap();
        let mut legacy = raw[..raw.len() - 4].to_vec();
        legacy[..8].copy_from_slice(LEGACY_MAGIC);
        std::fs::write(&p, &legacy).unwrap();
        let restored = load(&p, &e).unwrap();
        let back: Vec<f32> = restored.tensors[0]
            .convert(xla::PrimitiveType::F32)
            .unwrap()
            .to_vec()
            .unwrap();
        assert_eq!(back, vec![1.0, 2.0, 3.0]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
