//! Runtime stand-in for builds without the `pjrt` feature.
//!
//! Mirrors the public API of [`exec`]/[`state_io`] so the trainer, CLI,
//! benches and examples compile unchanged; every entry point that would
//! need a real PJRT backend fails with [`NO_PJRT`]. The rest of the crate
//! (data pipeline, producer pool, memory simulator, checkpoint planner) is
//! fully functional without the feature — which is exactly what the tier-1
//! test environment exercises.
//!
//! [`exec`]: crate::runtime
//! [`state_io`]: crate::runtime::state_io

use crate::data::loader::BatchPayload;
use crate::memory::arena::ArenaAllocator;
use crate::memory::offload::{OffloadEngine, OffloadStats, SpillPlan};
use crate::runtime::manifest::{Manifest, ManifestEntry};
use anyhow::{bail, Result};
use std::cell::RefCell;
use std::path::Path;

/// The error every backend-requiring path reports.
pub const NO_PJRT: &str = "optorch was built without the `pjrt` feature; \
    rebuild with `cargo build --features pjrt` (and point \
    rust/vendor/xla-stub at the real `xla` crate) to execute AOT artifacts";

/// Stub of the PJRT client + executable cache. Never constructible.
pub struct Runtime {
    manifest: Manifest,
}

/// Training state: host-side f32 tensors in manifest order. The stub keeps
/// the same shape of API (`len`/`bytes`/public `tensors`) as the real
/// `Literal`-backed state.
pub struct TrainState {
    pub tensors: Vec<Vec<f32>>,
}

impl TrainState {
    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total bytes held.
    pub fn bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.len() * 4).sum()
    }
}

/// Output of one train/eval step.
#[derive(Clone, Copy, Debug)]
pub struct StepOutput {
    pub loss: f32,
    /// Correct predictions in the batch.
    pub correct: u32,
    pub batch_size: u32,
}

impl StepOutput {
    pub fn accuracy(&self) -> f64 {
        if self.batch_size == 0 {
            0.0
        } else {
            self.correct as f64 / self.batch_size as f64
        }
    }
}

/// Stub of a (model, pipeline)'s compiled executables.
pub struct LoadedModel {
    pub entry: ManifestEntry,
    /// Mirror of the real runtime's per-step marshaling arena
    /// ([`crate::memory::arena::ArenaAllocator`]), so stub and PJRT builds
    /// expose the same surface (sized by
    /// [`ManifestEntry::step_scratch_bytes`]).
    scratch: RefCell<ArenaAllocator>,
    /// Mirror of the real runtime's host-spill engine slot.
    offload: RefCell<Option<OffloadEngine>>,
}

impl Runtime {
    /// Always fails: executing artifacts needs the `pjrt` feature.
    pub fn new(_artifacts_dir: &Path) -> Result<Runtime> {
        bail!(NO_PJRT);
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn load(&mut self, _model: &str, _pipeline: &str) -> Result<LoadedModel> {
        bail!(NO_PJRT);
    }
}

impl LoadedModel {
    /// The per-step marshaling arena (same accessor as the PJRT runtime).
    pub fn scratch_arena(&self) -> &RefCell<ArenaAllocator> {
        &self.scratch
    }

    /// Install a host-spill plan (same surface as the PJRT runtime; the
    /// engine is pure host-side bookkeeping, so it works in the stub too).
    pub fn configure_offload(&self, plan: &SpillPlan) {
        *self.offload.borrow_mut() = Some(OffloadEngine::new(plan));
    }

    /// Engine counters (`None` when no spill plan is installed).
    pub fn offload_stats(&self) -> Option<OffloadStats> {
        self.offload.borrow().as_ref().map(OffloadEngine::stats)
    }

    /// Host-pool resident high-water within the most recent replayed step
    /// (`None` when no spill plan is installed) — the per-step gauge
    /// behind `optorch_host_resident_bytes`.
    pub fn offload_step_host_peak(&self) -> Option<u64> {
        self.offload.borrow().as_ref().map(OffloadEngine::last_step_host_peak_bytes)
    }

    /// Inject (or clear) a deterministic link-fault model on the installed
    /// offload engine (same surface as the PJRT runtime). No-op until
    /// [`LoadedModel::configure_offload`] ran.
    pub fn configure_link_faults(&self, link: Option<crate::memory::offload::LinkFaults>) {
        if let Some(engine) = self.offload.borrow_mut().as_mut() {
            engine.set_link_faults(link);
        }
    }

    /// Hand the installed offload engine a per-thread trace buffer (same
    /// surface as the PJRT runtime). No-op until
    /// [`LoadedModel::configure_offload`] ran; a replan replaces the
    /// engine, so callers re-install the tracer afterwards.
    pub fn configure_trace(&self, trace: crate::trace::ThreadTracer) {
        if let Some(engine) = self.offload.borrow_mut().as_mut() {
            engine.set_tracer(trace);
        }
    }

    /// Remove the installed host-spill plan (degradation abandoned
    /// spilling, e.g. the heap-fallback rung).
    pub fn clear_offload(&self) {
        *self.offload.borrow_mut() = None;
    }

    pub fn init_state(&self, _seed: u64) -> Result<TrainState> {
        bail!(NO_PJRT);
    }

    pub fn train_step(&self, _state: &mut TrainState, _payload: &BatchPayload) -> Result<StepOutput> {
        bail!(NO_PJRT);
    }

    pub fn train_step_lr(
        &self,
        _state: &mut TrainState,
        _payload: &BatchPayload,
        _lr: f32,
    ) -> Result<StepOutput> {
        bail!(NO_PJRT);
    }

    pub fn eval_step(&self, _state: &TrainState, _payload: &BatchPayload) -> Result<StepOutput> {
        bail!(NO_PJRT);
    }
}

/// Checkpoint save/load stand-ins (same signatures as the real module).
pub mod state_io {
    use super::{ManifestEntry, TrainState, NO_PJRT};
    use anyhow::{bail, Result};
    use std::path::Path;

    pub fn save(_path: &Path, _entry: &ManifestEntry, _state: &TrainState) -> Result<()> {
        bail!(NO_PJRT);
    }

    pub fn load(_path: &Path, _entry: &ManifestEntry) -> Result<TrainState> {
        bail!(NO_PJRT);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_construction_reports_missing_feature() {
        let err = Runtime::new(Path::new("artifacts")).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn stub_model_exposes_the_step_arena() {
        use crate::runtime::manifest::{BatchKind, Dtype, TensorSpec};
        let entry = ManifestEntry {
            model: "m".into(),
            pipeline: "ed".into(),
            input: (2, 2, 3),
            num_classes: 3,
            batch_size: 2,
            groups: 2,
            group_capacity: 6,
            batch_kind: BatchKind::Encoded,
            batch_spec: TensorSpec {
                name: "batch".into(),
                shape: vec![2, 2, 2, 3],
                dtype: Dtype::F64,
            },
            labels_spec: TensorSpec {
                name: "labels".into(),
                shape: vec![2, 3],
                dtype: Dtype::F32,
            },
            state: vec![TensorSpec { name: "w".into(), shape: vec![3], dtype: Dtype::F32 }],
            train_hlo: "x".into(),
            eval_hlo: "x".into(),
            init_hlo: "x".into(),
            lr: 0.1,
            momentum: 0.9,
            loss_scale: 1.0,
            device_budget: None,
        };
        let model = LoadedModel {
            scratch: RefCell::new(ArenaAllocator::new(entry.step_scratch_bytes())),
            offload: RefCell::new(None),
            entry,
        };
        let mut arena = model.scratch_arena().borrow_mut();
        // 2 groups × 12 px × 8 B words + 2×3 f32 labels (both 8-aligned)
        assert_eq!(arena.slab_bytes(), 2 * 12 * 8 + 2 * 3 * 4);
        arena.begin_step();
        let h = arena.alloc_f64(2 * 12).unwrap();
        assert_eq!(arena.f64_mut(&h).len(), 24);
        assert_eq!(arena.fallback_allocs(), 0);
        assert!(arena.alloc(1 << 20).is_none(), "oversize falls back");
        assert_eq!(arena.fallback_allocs(), 1);
        drop(arena);

        // the host-spill engine surface matches the PJRT runtime's
        assert!(model.offload_stats().is_none());
        let arch = crate::models::arch_by_name("tiny_cnn", (32, 32, 3), 10).unwrap();
        let sc = crate::config::Pipeline::parse("sc").unwrap();
        let plan =
            crate::memory::offload::plan_spill(&arch, sc, 2, &[0, 1], u64::MAX, 2).unwrap();
        model.configure_offload(&plan);
        let stats = model.offload_stats().unwrap();
        assert_eq!(stats.steps, 0);
        assert_eq!(stats.evictions, 0);
        model.configure_link_faults(Some(crate::memory::offload::LinkFaults {
            seed: 7,
            fail_prob: 1.0,
            ..Default::default()
        }));
        model.clear_offload();
        assert!(model.offload_stats().is_none());
    }

    #[test]
    fn state_shape_helpers() {
        let s = TrainState { tensors: vec![vec![0.0; 4], vec![0.0; 2]] };
        assert_eq!(s.len(), 2);
        assert!(!s.is_empty());
        assert_eq!(s.bytes(), 24);
        let out = StepOutput { loss: 1.0, correct: 3, batch_size: 4 };
        assert!((out.accuracy() - 0.75).abs() < 1e-9);
    }
}
