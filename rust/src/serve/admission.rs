//! Admission control: typed shed reasons and the sustained-overload
//! detector that walks the degradation ladder.
//!
//! Every request ends in exactly one of two outcomes — completed, or
//! shed with a [`ShedReason`] the client can act on. Shedding is a
//! *feature*: refusing work the tier cannot finish inside its budget
//! and deadline keeps the latency of admitted work predictable. The
//! [`OverloadDetector`] watches the recent admit/shed stream and fires
//! once the shed fraction stays above a threshold, at which point the
//! engine steps down the ladder (smaller max batch, then heap-fallback
//! arena) instead of thrashing.

use std::fmt;

/// Why a request was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// The bounded request queue was at capacity.
    QueueFull,
    /// No micro-batch size — not even 1 — fits the device budget.
    BudgetExceeded,
    /// The request would have completed past its deadline; refusing at
    /// dispatch beats burning device time on an answer nobody waits for.
    DeadlineExceeded,
}

impl ShedReason {
    /// Stable kebab-case tag shared by the JSON report and `/metrics`.
    pub fn kind(self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue-full",
            ShedReason::BudgetExceeded => "budget-exceeded",
            ShedReason::DeadlineExceeded => "deadline-exceeded",
        }
    }
}

impl fmt::Display for ShedReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.kind())
    }
}

/// Sliding-window shed-rate detector.
///
/// A fixed ring of the last `window` admission decisions; `check` reports
/// the shed fraction once the window is at least half full and the
/// fraction exceeds `threshold`. The engine calls [`OverloadDetector::reset`]
/// after taking a ladder rung so one burst is not double-counted.
pub struct OverloadDetector {
    slots: Vec<bool>,
    window: usize,
    head: usize,
    len: usize,
    threshold: f64,
}

impl OverloadDetector {
    pub fn new(window: usize, threshold: f64) -> OverloadDetector {
        let window = window.max(1);
        OverloadDetector {
            slots: vec![false; window],
            window,
            head: 0,
            len: 0,
            threshold: threshold.clamp(0.0, 1.0),
        }
    }

    /// Record one admission decision.
    pub fn note(&mut self, shed: bool) {
        self.slots[self.head] = shed;
        self.head = (self.head + 1) % self.window;
        self.len = (self.len + 1).min(self.window);
    }

    /// Shed fraction over the valid window (0.0 while empty).
    pub fn rate(&self) -> f64 {
        if self.len == 0 {
            return 0.0;
        }
        let sheds = self.slots[..self.len].iter().filter(|&&s| s).count();
        sheds as f64 / self.len as f64
    }

    /// `Some(rate)` when the window is warm (≥ half full) and the shed
    /// rate exceeds the threshold — the signal to take a ladder rung.
    pub fn check(&self) -> Option<f64> {
        if self.len * 2 < self.window {
            return None;
        }
        let rate = self.rate();
        if rate > self.threshold {
            Some(rate)
        } else {
            None
        }
    }

    /// Forget the window (called after a ladder rung is applied).
    pub fn reset(&mut self) {
        self.slots.iter_mut().for_each(|s| *s = false);
        self.head = 0;
        self.len = 0;
    }

    pub fn window(&self) -> usize {
        self.window
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reasons_have_stable_tags() {
        assert_eq!(ShedReason::QueueFull.kind(), "queue-full");
        assert_eq!(ShedReason::BudgetExceeded.kind(), "budget-exceeded");
        assert_eq!(ShedReason::DeadlineExceeded.kind(), "deadline-exceeded");
        assert_eq!(ShedReason::QueueFull.to_string(), "queue-full");
    }

    #[test]
    fn detector_fires_only_when_warm_and_over_threshold() {
        let mut d = OverloadDetector::new(8, 0.25);
        // 3 sheds in a 3-deep window: rate 1.0 but window cold → no fire
        for _ in 0..3 {
            d.note(true);
        }
        assert_eq!(d.check(), None, "cold window never fires");
        d.note(false);
        // warm now (4 of 8): 3/4 shed > 0.25
        let rate = d.check().expect("warm + over threshold fires");
        assert!((rate - 0.75).abs() < 1e-12, "{rate}");
        d.reset();
        assert_eq!(d.rate(), 0.0);
        assert_eq!(d.check(), None);
        // all admits: never fires regardless of fill
        for _ in 0..16 {
            d.note(false);
        }
        assert_eq!(d.check(), None);
    }

    #[test]
    fn window_wraps_and_ages_out_old_sheds() {
        let mut d = OverloadDetector::new(4, 0.0);
        d.note(true);
        for _ in 0..4 {
            d.note(false);
        }
        assert_eq!(d.rate(), 0.0, "the shed aged out of the 4-slot window");
    }
}
