//! Dynamic micro-batching policy.
//!
//! Pure decision logic, separated from the engine's event loop so it can
//! be unit-tested without a simulation: given the queue state and the
//! virtual clock, [`MicroBatcher::decide`] says *dispatch now with this
//! batch size*, *wait until this time*, or *nothing to do*. The policy
//! is the classic deadline-bounded coalescing triangle:
//!
//! * a full batch (`queue ≥ max_batch`) dispatches immediately — waiting
//!   cannot grow it further;
//! * an undersized batch waits up to `window_secs` past the head
//!   request's arrival, trading a bounded latency hit for a larger (more
//!   efficient) batch;
//! * when no further arrivals are possible (all clients blocked or the
//!   workload is drained) waiting is pointless, so whatever is queued
//!   dispatches at once.

/// What the batcher wants the engine to do next.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BatchDecision {
    /// Pop `size` requests and dispatch them now.
    Dispatch { size: usize },
    /// Re-evaluate at `at_secs` (the head request's coalescing window
    /// expiry) unless an arrival lands first.
    WaitUntil { at_secs: f64 },
    /// Queue empty: nothing to decide.
    Idle,
}

/// The coalescing policy knobs. `max_batch` is mutable at runtime — the
/// degradation ladder halves it under sustained overload.
pub struct MicroBatcher {
    max_batch: usize,
    window_secs: f64,
}

impl MicroBatcher {
    pub fn new(max_batch: usize, window_secs: f64) -> MicroBatcher {
        MicroBatcher { max_batch: max_batch.max(1), window_secs: window_secs.max(0.0) }
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Shrink the batch ceiling (ladder rung); returns the new ceiling.
    pub fn set_max_batch(&mut self, max_batch: usize) -> usize {
        self.max_batch = max_batch.max(1);
        self.max_batch
    }

    /// Decide for the current instant. `oldest_arrival_secs` is the head
    /// request's arrival (None = empty queue); `arrivals_possible` is
    /// whether any client could still enqueue before the window expires.
    pub fn decide(
        &self,
        queue_len: usize,
        oldest_arrival_secs: Option<f64>,
        now_secs: f64,
        arrivals_possible: bool,
    ) -> BatchDecision {
        let Some(oldest) = oldest_arrival_secs else {
            return BatchDecision::Idle;
        };
        if queue_len == 0 {
            return BatchDecision::Idle;
        }
        if queue_len >= self.max_batch {
            return BatchDecision::Dispatch { size: self.max_batch };
        }
        let expiry = oldest + self.window_secs;
        if !arrivals_possible || now_secs >= expiry {
            return BatchDecision::Dispatch { size: queue_len };
        }
        BatchDecision::WaitUntil { at_secs: expiry }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_queue_dispatches_at_ceiling() {
        let b = MicroBatcher::new(4, 0.010);
        assert_eq!(
            b.decide(9, Some(0.0), 0.0, true),
            BatchDecision::Dispatch { size: 4 },
            "never exceeds max_batch even with a deeper queue"
        );
    }

    #[test]
    fn undersized_batch_waits_out_the_window_then_goes() {
        let b = MicroBatcher::new(4, 0.010);
        assert_eq!(
            b.decide(2, Some(1.0), 1.002, true),
            BatchDecision::WaitUntil { at_secs: 1.010 }
        );
        assert_eq!(
            b.decide(2, Some(1.0), 1.010, true),
            BatchDecision::Dispatch { size: 2 },
            "window expiry flushes the partial batch"
        );
    }

    #[test]
    fn no_possible_arrivals_short_circuits_the_wait() {
        let b = MicroBatcher::new(8, 1.0);
        assert_eq!(
            b.decide(3, Some(5.0), 5.0, false),
            BatchDecision::Dispatch { size: 3 },
            "waiting for arrivals that cannot happen only adds latency"
        );
    }

    #[test]
    fn empty_queue_is_idle_and_ladder_shrinks_ceiling() {
        let mut b = MicroBatcher::new(8, 0.010);
        assert_eq!(b.decide(0, None, 0.0, true), BatchDecision::Idle);
        assert_eq!(b.set_max_batch(4), 4);
        assert_eq!(b.set_max_batch(0), 1, "ceiling clamps to 1");
        assert_eq!(
            b.decide(2, Some(0.0), 0.0, true),
            BatchDecision::Dispatch { size: 1 }
        );
    }
}
