//! LRU cache over staged [`PlanOutcome`]s, keyed by everything that
//! changes a forward plan.
//!
//! Serving re-plans constantly — every micro-batch size the batcher
//! coalesces needs its own forward-only plan — but the plan space is
//! tiny: one arch, a handful of batch sizes, one budget. Resolving each
//! dispatch through [`PlanCache::get_or_insert_with`] means the packing
//! runs once per distinct `(arch, batch, budget, bw)` and every later
//! dispatch is a move-to-front list probe: microseconds, not a DP.

use crate::memory::outcome::PlanOutcome;
use crate::memory::pipeline::PlanError;
use std::sync::Arc;

/// Everything that distinguishes one cached plan from another.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub arch: String,
    pub batch: usize,
    /// Device budget the plan was solved under (`None` = heap fallback).
    pub budget: Option<u64>,
    pub host_bw: u64,
}

/// A deterministic LRU over `(PlanKey, Arc<PlanOutcome>)` pairs.
///
/// Backed by a move-to-front `Vec` rather than a hash map: the working
/// set is a few dozen entries at most, probes are a linear scan of
/// inline keys, and eviction order is exactly insertion-recency — no
/// hasher state to make two runs disagree.
pub struct PlanCache {
    entries: Vec<(PlanKey, Arc<PlanOutcome>)>,
    capacity: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl PlanCache {
    /// A cache holding at most `capacity` plans (clamped to ≥ 1).
    pub fn new(capacity: usize) -> PlanCache {
        PlanCache {
            entries: Vec::new(),
            capacity: capacity.max(1),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Resolve `key`, planning via `f` only on a miss. Errors from `f`
    /// are returned uncached, so an infeasible batch size re-asks the
    /// planner (callers avoid that by probing feasibility once per
    /// ladder state, not per dispatch).
    pub fn get_or_insert_with<F>(&mut self, key: &PlanKey, f: F) -> Result<Arc<PlanOutcome>, PlanError>
    where
        F: FnOnce() -> Result<PlanOutcome, PlanError>,
    {
        if let Some(pos) = self.entries.iter().position(|(k, _)| k == key) {
            self.hits += 1;
            let entry = self.entries.remove(pos);
            let outcome = Arc::clone(&entry.1);
            self.entries.insert(0, entry);
            return Ok(outcome);
        }
        self.misses += 1;
        let outcome = Arc::new(f()?);
        self.entries.insert(0, (key.clone(), Arc::clone(&outcome)));
        while self.entries.len() > self.capacity {
            self.entries.pop();
            self.evictions += 1;
        }
        Ok(outcome)
    }

    /// Whether `key` is resident (no LRU touch, no counters).
    pub fn contains(&self, key: &PlanKey) -> bool {
        self.entries.iter().any(|(k, _)| k == key)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::pipeline::{PlanMode, PlanRequest};

    fn key(batch: usize) -> PlanKey {
        PlanKey {
            arch: "resnet18".to_string(),
            batch,
            budget: None,
            host_bw: 1 << 30,
        }
    }

    fn plan(batch: usize) -> Result<PlanOutcome, PlanError> {
        PlanRequest::for_model("resnet18", (64, 64, 3), 10)
            .batch(batch)
            .mode(PlanMode::Infer)
            .run()
    }

    #[test]
    fn second_lookup_hits_without_replanning() {
        let mut cache = PlanCache::new(4);
        let mut planned = 0;
        for _ in 0..3 {
            let out = cache
                .get_or_insert_with(&key(8), || {
                    planned += 1;
                    plan(8)
                })
                .unwrap();
            assert_eq!(out.batch, 8);
        }
        assert_eq!(planned, 1, "the DP-free packing still runs exactly once");
        assert_eq!(cache.hits(), 2);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.evictions(), 0);
    }

    #[test]
    fn lru_evicts_least_recently_used_deterministically() {
        let mut cache = PlanCache::new(2);
        cache.get_or_insert_with(&key(1), || plan(1)).unwrap();
        cache.get_or_insert_with(&key(2), || plan(2)).unwrap();
        // touch batch 1 so batch 2 is now least-recent
        cache.get_or_insert_with(&key(1), || plan(1)).unwrap();
        cache.get_or_insert_with(&key(4), || plan(4)).unwrap();
        assert_eq!(cache.len(), 2);
        assert!(cache.contains(&key(1)), "recently touched survives");
        assert!(cache.contains(&key(4)));
        assert!(!cache.contains(&key(2)), "LRU entry evicted");
        assert_eq!(cache.evictions(), 1);
    }

    #[test]
    fn errors_are_not_cached() {
        let mut cache = PlanCache::new(2);
        let mut calls = 0;
        for _ in 0..2 {
            let r = cache.get_or_insert_with(&key(3), || {
                calls += 1;
                Err(PlanError::UnknownArch { model: "nope".to_string() })
            });
            assert!(r.is_err());
        }
        assert_eq!(calls, 2, "a failed plan is re-asked, never resident");
        assert!(cache.is_empty());
    }

    #[test]
    fn distinct_budgets_are_distinct_entries() {
        let mut cache = PlanCache::new(4);
        let a = PlanKey { budget: Some(1 << 30), ..key(8) };
        let b = PlanKey { budget: None, ..key(8) };
        cache
            .get_or_insert_with(&a, || plan(8))
            .unwrap();
        cache.get_or_insert_with(&b, || plan(8)).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.misses(), 2);
    }
}
