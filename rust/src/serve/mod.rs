//! Inference serving: dynamic micro-batching, forward-only arenas, and
//! admission control.
//!
//! Training and serving want opposite things from the memory stack. A
//! trainer plans *once* for one big batch and amortizes the DP over an
//! epoch; a serving tier fields a stream of single-image requests whose
//! only memory need is the forward pass — no gradients, no momentum, no
//! recompute question. This module is the serving half, built from the
//! same parts the trainer uses:
//!
//! * **Forward-only plans** — every dispatch resolves through
//!   [`PlanRequest`] in [`PlanMode::Infer`]: the evaluator's exact
//!   forward replay ([`Lifetimes::extract_infer`]) packed directly into
//!   a slab, strictly smaller than any training plan over the same
//!   arch/batch. Plans are memoized in a [`PlanCache`] keyed by
//!   `(arch, batch, budget, bw)`, so per-request planning is a
//!   move-to-front probe, not a DP.
//! * **Dynamic micro-batching** — a bounded [`BoundedQueue`] feeds a
//!   [`MicroBatcher`] that coalesces requests into the largest batch
//!   whose cached forward plan fits the device budget, waiting at most a
//!   fixed window past the head request's arrival. Request payloads ride
//!   the E-D encode path with every buffer drawn from a
//!   [`BufferPool`](crate::data::pool::BufferPool), so steady-state
//!   dispatches allocate nothing pool-managed.
//! * **Admission control** — requests the tier cannot finish are shed
//!   with a typed [`ShedReason`] (queue full, budget exceeded, deadline
//!   exceeded). Sustained overload — a shed rate above threshold across
//!   the [`OverloadDetector`] window — walks the same degradation ladder
//!   the trainer uses: halve the batch ceiling
//!   ([`DegradationAction::ReducedMaxBatch`]), and when that is spent,
//!   abandon the budget for a heap-backed arena
//!   ([`DegradationAction::HeapFallbackArena`]), reported as a typed
//!   [`DegradationReport`].
//!
//! The engine is a deterministic discrete-event simulation over a
//! virtual clock: closed-loop synthetic clients (seeded [`Rng`] think
//! times) issue requests, a serial device executes micro-batches at the
//! cached plan's predicted step time plus the modeled decode transfer,
//! and every latency is exact virtual time. Same config + seed → the
//! same [`ServeReport`] byte for byte, which is what lets CI gate
//! `BENCH_serve.json` against a baseline.
//!
//! Surfaced as `optorch serve --arch resnet18 --budget 2GiB --max_batch
//! 16 --deadline_ms 50 [--metrics_addr HOST:PORT]`; the live
//! `/metrics` endpoint exposes queue depth, admitted/shed counters and
//! the batch-size histogram, and `/readyz` reports 503 while the shed
//! rate over the sample window is nonzero.
//!
//! [`PlanRequest`]: crate::memory::pipeline::PlanRequest
//! [`PlanMode::Infer`]: crate::memory::pipeline::PlanMode::Infer
//! [`Lifetimes::extract_infer`]: crate::memory::arena::Lifetimes::extract_infer

mod admission;
mod batcher;
mod cache;
mod queue;
mod report;

pub use admission::{OverloadDetector, ShedReason};
pub use batcher::{BatchDecision, MicroBatcher};
pub use cache::{PlanCache, PlanKey};
pub use queue::{BoundedQueue, Request};
pub use report::ServeReport;

use crate::config::kv::{parse_kv, KvGet};
use crate::data::encode::{
    decode_batch, encode_batch_grouped_into, EncodeError, EncodeSpec, Encoding, WordType,
};
use crate::data::image::ImageBatch;
use crate::data::loader::BatchPayload;
use crate::data::pool::BufferPool;
use crate::fault::{DegradationAction, DegradationReport, DegradeTrigger};
use crate::memory::outcome::PlanOutcome;
use crate::memory::offload::DEFAULT_HOST_BW_BYTES_PER_SEC;
use crate::memory::pipeline::{parse_bytes_field, PlanError, PlanMode, PlanRequest};
use crate::metrics::Histogram;
use crate::obs::MetricsHub;
use crate::trace::PhaseStat;
use crate::util::rng::Rng;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Typed failures of the serving tier.
#[derive(Debug)]
pub enum ServeError {
    /// Bad config file or override.
    Config(String),
    /// The planning facade refused (unknown arch, bad bytes, …).
    Plan(PlanError),
    /// The request encoder refused (capacity, empty batch).
    Encode(EncodeError),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Config(m) => write!(f, "{m}"),
            ServeError::Plan(e) => write!(f, "{e}"),
            ServeError::Encode(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for ServeError {}

impl From<PlanError> for ServeError {
    fn from(e: PlanError) -> ServeError {
        ServeError::Plan(e)
    }
}

/// Knobs of one serving run. Mirrors [`TrainConfig`]'s sourcing: a
/// TOML-subset config file plus `--key value` overrides, validated once.
///
/// [`TrainConfig`]: crate::config::TrainConfig
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Registry architecture to serve (see `optorch models`).
    pub model: String,
    /// Input image shape `(h, w, c)`.
    pub input: (usize, usize, usize),
    pub classes: usize,
    /// Device budget the cached forward plans must fit, if any.
    pub budget: Option<u64>,
    /// Micro-batch ceiling before the ladder shrinks it.
    pub max_batch: usize,
    /// Per-request latency deadline; predicted-late requests are shed.
    pub deadline_ms: f64,
    /// How long an undersized batch may wait for co-riders.
    pub batch_window_ms: f64,
    /// Closed-loop synthetic clients.
    pub clients: usize,
    /// Total requests the clients issue.
    pub requests: usize,
    /// Mean client think time between response and next request.
    pub think_ms: f64,
    /// Bounded request-queue capacity.
    pub queue_cap: usize,
    /// Modeled host→device bandwidth for request payload transfer.
    pub host_bw: u64,
    pub seed: u64,
    /// Optional `/metrics` + `/healthz` + `/readyz` listener address.
    pub metrics_addr: Option<String>,
    /// Admission decisions in the overload / readiness window.
    pub shed_window: usize,
    /// Windowed shed rate above which the ladder is walked.
    pub overload_shed_rate: f64,
    /// Plan-cache capacity (distinct `(arch, batch, budget, bw)` keys).
    pub plan_cache_cap: usize,
}

impl ServeConfig {
    /// Sensible defaults for a registry model.
    pub fn default_for(model: &str) -> ServeConfig {
        ServeConfig {
            model: model.to_string(),
            input: (64, 64, 3),
            classes: 10,
            budget: None,
            max_batch: 16,
            deadline_ms: 50.0,
            batch_window_ms: 2.0,
            clients: 8,
            requests: 512,
            think_ms: 1.0,
            queue_cap: 64,
            host_bw: DEFAULT_HOST_BW_BYTES_PER_SEC,
            seed: 42,
            metrics_addr: None,
            shed_window: 64,
            overload_shed_rate: 0.5,
            plan_cache_cap: 32,
        }
    }

    /// Parse a config file + `--key value` CLI overrides (the same
    /// sourcing contract as `TrainConfig::from_sources`).
    pub fn from_sources(
        file_text: Option<&str>,
        overrides: &BTreeMap<String, String>,
    ) -> Result<ServeConfig, String> {
        let mut kv = match file_text {
            Some(t) => parse_kv(t).map_err(|e| e.to_string())?,
            None => BTreeMap::new(),
        };
        for (k, v) in overrides {
            kv.insert(k.clone(), v.clone());
        }
        let mut cfg = ServeConfig::default_for("resnet18");
        // `arch` is the documented knob; `model` is accepted as the alias
        // every other subcommand uses.
        if let Some(m) = kv.get_str("arch").or_else(|| kv.get_str("model")) {
            cfg.model = m.to_string();
        }
        let h = kv.get_usize("height")?.unwrap_or(cfg.input.0);
        let w = kv.get_usize("width")?.unwrap_or(cfg.input.1);
        cfg.input = (h, w, cfg.input.2);
        if let Some(v) = kv.get_usize("classes")? {
            cfg.classes = v;
        }
        if let Some(v) = kv.get_str("budget") {
            cfg.budget =
                Some(parse_bytes_field("budget", v).map_err(|e| e.to_string())?);
        }
        if let Some(v) = kv.get_usize("max_batch")? {
            cfg.max_batch = v;
        }
        if let Some(v) = kv.get_f64("deadline_ms")? {
            cfg.deadline_ms = v;
        }
        if let Some(v) = kv.get_f64("batch_window_ms")? {
            cfg.batch_window_ms = v;
        }
        if let Some(v) = kv.get_usize("clients")? {
            cfg.clients = v;
        }
        if let Some(v) = kv.get_usize("requests")? {
            cfg.requests = v;
        }
        if let Some(v) = kv.get_f64("think_ms")? {
            cfg.think_ms = v;
        }
        if let Some(v) = kv.get_usize("queue_cap")? {
            cfg.queue_cap = v;
        }
        if let Some(v) = kv.get_str("host_bw") {
            cfg.host_bw = parse_bytes_field("host_bw", v).map_err(|e| e.to_string())?;
        }
        if let Some(v) = kv.get_usize("seed")? {
            cfg.seed = v as u64;
        }
        if let Some(v) = kv.get_str("metrics_addr") {
            cfg.metrics_addr = Some(v.to_string());
        }
        if let Some(v) = kv.get_usize("shed_window")? {
            cfg.shed_window = v;
        }
        if let Some(v) = kv.get_f64("overload_shed_rate")? {
            cfg.overload_shed_rate = v;
        }
        if let Some(v) = kv.get_usize("plan_cache_cap")? {
            cfg.plan_cache_cap = v;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    fn validate(&self) -> Result<(), String> {
        if self.model.is_empty() {
            return Err("arch: must name a registry architecture".into());
        }
        if self.input.0 == 0 || self.input.1 == 0 || self.input.2 == 0 {
            return Err("height/width: must be ≥ 1".into());
        }
        if self.classes == 0 {
            return Err("classes: must be ≥ 1".into());
        }
        if self.max_batch == 0 {
            return Err("max_batch: must be ≥ 1".into());
        }
        if !(self.deadline_ms > 0.0) {
            return Err("deadline_ms: must be > 0".into());
        }
        if self.batch_window_ms < 0.0 {
            return Err("batch_window_ms: must be ≥ 0".into());
        }
        if self.clients == 0 {
            return Err("clients: must be ≥ 1".into());
        }
        if self.requests == 0 {
            return Err("requests: must be ≥ 1".into());
        }
        if self.think_ms < 0.0 {
            return Err("think_ms: must be ≥ 0".into());
        }
        if self.queue_cap == 0 {
            return Err("queue_cap: must be ≥ 1".into());
        }
        if self.host_bw == 0 {
            return Err("host_bw: must be ≥ 1".into());
        }
        if self.shed_window == 0 {
            return Err("shed_window: must be ≥ 1".into());
        }
        if !(0.0..1.0).contains(&self.overload_shed_rate) {
            return Err("overload_shed_rate: must be in [0, 1)".into());
        }
        if self.plan_cache_cap == 0 {
            return Err("plan_cache_cap: must be ≥ 1".into());
        }
        Ok(())
    }
}

/// Run one closed-loop serving simulation, streaming gauges into `hub`.
pub fn run(cfg: &ServeConfig, hub: &MetricsHub) -> Result<ServeReport, ServeError> {
    Engine::new(cfg, hub)?.run()
}

/// One synthetic closed-loop client: thinks, issues, blocks on the
/// response (or an immediate shed), thinks again.
struct Client {
    rng: Rng,
    /// Next issue instant; meaningful only while not waiting.
    next_issue_secs: f64,
    /// True while a request of this client is queued or in flight.
    waiting: bool,
}

/// Which timed event fires next in the simulation.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Event {
    /// The in-flight micro-batch completes.
    Completion,
    /// Client `i` issues its next request.
    Arrival(usize),
    /// The batcher's coalescing window for the head request expires.
    DispatchCheck,
}

struct Engine<'a> {
    cfg: &'a ServeConfig,
    hub: &'a MetricsHub,
    cache: PlanCache,
    batcher: MicroBatcher,
    queue: BoundedQueue,
    detector: OverloadDetector,
    pool: BufferPool,
    spec: EncodeSpec,
    clients: Vec<Client>,
    payload_rng: Rng,
    /// Virtual clock, seconds.
    now: f64,
    issued: u64,
    completed: u64,
    shed_queue_full: u64,
    shed_budget: u64,
    shed_deadline: u64,
    /// Current device budget (`None` after the heap-fallback rung).
    budget: Option<u64>,
    /// Ladder-controlled batch ceiling (starts at `cfg.max_batch`).
    policy_max: usize,
    /// Largest batch ≤ `policy_max` whose forward plan fits `budget`
    /// (0 = not even batch 1 fits: every request sheds).
    eff_max: usize,
    /// The dispatched batch and its completion instant (serial device).
    inflight: Option<(Vec<Request>, f64)>,
    /// Exact per-request latencies, virtual seconds (for exact quantiles).
    latencies: Vec<f64>,
    queue_wait_ns: Histogram,
    service_ns: Histogram,
    e2e_ns: Histogram,
    batch_hist: BTreeMap<usize, u64>,
    trigger: Option<DegradeTrigger>,
    actions: Vec<DegradationAction>,
    first_arrival: Option<f64>,
    last_response: f64,
    /// Payload bytes of one capacity-sized encoded group (decode model).
    group_payload_bytes: u64,
}

/// One think interval: uniform in `[0.5, 1.5) ×` the configured mean.
fn think_secs(rng: &mut Rng, think_ms: f64) -> f64 {
    think_ms / 1e3 * (0.5 + rng.f64())
}

/// Exact quantile of an ascending-sorted slice (nearest-rank).
fn exact_quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

impl<'a> Engine<'a> {
    fn new(cfg: &'a ServeConfig, hub: &'a MetricsHub) -> Result<Engine<'a>, ServeError> {
        cfg.validate().map_err(ServeError::Config)?;
        hub.enable_serve_mode(cfg.shed_window);
        let root = Rng::new(cfg.seed);
        let clients = (0..cfg.clients)
            .map(|i| {
                let mut rng = root.split(1_000 + i as u64);
                let first = think_secs(&mut rng, cfg.think_ms);
                Client { rng, next_issue_secs: first, waiting: false }
            })
            .collect();
        let spec = EncodeSpec::new(Encoding::Base256, WordType::U64);
        // One pixel position = one packed word, so a capacity-sized group
        // ships h·w·c words regardless of how many images ride in it.
        let (h, w, c) = cfg.input;
        let group_payload_bytes = (h * w * c * 8) as u64;
        let mut engine = Engine {
            cfg,
            hub,
            cache: PlanCache::new(cfg.plan_cache_cap),
            batcher: MicroBatcher::new(cfg.max_batch, cfg.batch_window_ms / 1e3),
            queue: BoundedQueue::new(cfg.queue_cap),
            detector: OverloadDetector::new(cfg.shed_window, cfg.overload_shed_rate),
            pool: BufferPool::default(),
            spec,
            clients,
            payload_rng: root.split(7),
            now: 0.0,
            issued: 0,
            completed: 0,
            shed_queue_full: 0,
            shed_budget: 0,
            shed_deadline: 0,
            budget: cfg.budget,
            policy_max: cfg.max_batch,
            eff_max: 0,
            inflight: None,
            latencies: Vec::with_capacity(cfg.requests),
            queue_wait_ns: Histogram::new(),
            service_ns: Histogram::new(),
            e2e_ns: Histogram::new(),
            batch_hist: BTreeMap::new(),
            trigger: None,
            actions: Vec::new(),
            first_arrival: None,
            last_response: 0.0,
            group_payload_bytes,
        };
        engine.refresh_eff_max()?;
        Ok(engine)
    }

    /// Resolve the forward plan for `batch` through the LRU cache.
    fn plan_for(&mut self, batch: usize) -> Result<Arc<PlanOutcome>, PlanError> {
        let key = PlanKey {
            arch: self.cfg.model.clone(),
            batch,
            budget: self.budget,
            host_bw: self.cfg.host_bw,
        };
        let model = self.cfg.model.clone();
        let input = self.cfg.input;
        let classes = self.cfg.classes;
        let host_bw = self.cfg.host_bw;
        let budget = self.budget;
        self.cache.get_or_insert_with(&key, move || {
            let mut req = PlanRequest::for_model(&model, input, classes)
                .batch(batch)
                .host_bw(host_bw)
                .mode(PlanMode::Infer);
            if let Some(b) = budget {
                req = req.memory_budget(b);
            }
            req.run()
        })
    }

    /// Recompute the largest feasible batch under the current budget and
    /// ceiling; called at startup and after every ladder rung.
    fn refresh_eff_max(&mut self) -> Result<(), ServeError> {
        self.eff_max = 0;
        let mut b = self.policy_max;
        while b >= 1 {
            match self.plan_for(b) {
                Ok(_) => {
                    self.eff_max = b;
                    break;
                }
                Err(PlanError::BudgetBelowPacked(_)) | Err(PlanError::BudgetBelowSpilled(_)) => {
                    b -= 1;
                }
                Err(e) => return Err(ServeError::Plan(e)),
            }
        }
        self.batcher.set_max_batch(self.eff_max.max(1));
        Ok(())
    }

    fn shed_total(&self) -> u64 {
        self.shed_queue_full + self.shed_budget + self.shed_deadline
    }

    /// Refuse one request: typed count, hub + detector note, immediate
    /// rejection response to the client, possible ladder walk.
    fn shed(&mut self, client: usize, reason: ShedReason) -> Result<(), ServeError> {
        match reason {
            ShedReason::QueueFull => self.shed_queue_full += 1,
            ShedReason::BudgetExceeded => self.shed_budget += 1,
            ShedReason::DeadlineExceeded => self.shed_deadline += 1,
        }
        self.hub.note_shed();
        self.detector.note(true);
        let c = &mut self.clients[client];
        c.waiting = false;
        let t = think_secs(&mut c.rng, self.cfg.think_ms);
        c.next_issue_secs = self.now + t;
        self.last_response = self.now;
        self.maybe_walk_ladder()
    }

    /// Take a degradation rung when the windowed shed rate says so.
    fn maybe_walk_ladder(&mut self) -> Result<(), ServeError> {
        let Some(rate) = self.detector.check() else {
            return Ok(());
        };
        if self.trigger.is_none() {
            self.trigger = Some(DegradeTrigger::Overload {
                shed_rate: rate,
                window: self.detector.window(),
            });
        }
        if self.policy_max > 1 {
            let from = self.policy_max;
            self.policy_max = (self.policy_max / 2).max(1);
            self.actions
                .push(DegradationAction::ReducedMaxBatch { from, to: self.policy_max });
        } else if self.budget.is_some() {
            self.actions.push(DegradationAction::HeapFallbackArena);
            self.budget = None;
        } else {
            // Ladder exhausted: nothing cheaper to fall back to.
            return Ok(());
        }
        self.detector.reset();
        self.hub.note_degrade_event(1);
        self.refresh_eff_max()
    }

    /// One client issues a request: admission decides queue vs shed.
    fn arrive(&mut self, client: usize) -> Result<(), ServeError> {
        let id = self.issued;
        self.issued += 1;
        if self.first_arrival.is_none() {
            self.first_arrival = Some(self.now);
        }
        if self.eff_max == 0 {
            return self.shed(client, ShedReason::BudgetExceeded);
        }
        if self.queue.is_full() {
            return self.shed(client, ShedReason::QueueFull);
        }
        let req = Request { id, client, arrival_secs: self.now };
        self.clients[client].waiting = true;
        self.queue
            .push(req)
            .expect("capacity checked above");
        self.hub.note_admitted();
        self.detector.note(false);
        self.hub.set_queue_depth(self.queue.len() as u64);
        Ok(())
    }

    /// Predicted wall seconds to answer a `batch`-sized dispatch:
    /// modeled payload transfer + the cached forward plan's step time.
    fn service_secs(&mut self, batch: usize) -> Result<f64, ServeError> {
        let plan = self.plan_for(batch)?;
        let step = plan.predicted_step_secs().unwrap_or(0.0);
        let cap = self.spec.capacity();
        let groups = (batch + cap - 1) / cap;
        let decode = (groups as u64 * self.group_payload_bytes) as f64 / self.cfg.host_bw as f64;
        Ok(decode + step)
    }

    /// Materialize + encode the dispatch payload through the pool — the
    /// E-D producer path doing duty as the request decoder. Steady state
    /// draws every buffer from the pool.
    fn encode_dispatch(&mut self, batch: usize) -> Result<(), ServeError> {
        let (h, w, c) = self.cfg.input;
        let pixels = h * w * c;
        let classes = self.cfg.classes;
        let mut data = self.pool.take_u8(batch * pixels);
        data.resize(batch * pixels, 0);
        let mut labels = self.pool.take_f32(batch * classes);
        labels.resize(batch * classes, 0.0);
        let mut img = ImageBatch { n: batch, h, w, c, data, labels, num_classes: classes };
        // A deterministic non-trivial payload: one random byte per image.
        for i in 0..batch {
            img.data[i * pixels] = (self.payload_rng.next_u64() & 0xff) as u8;
        }
        let mut groups = self.pool.take_shells();
        encode_batch_grouped_into(&img, self.spec, &self.pool, &mut groups)
            .map_err(ServeError::Encode)?;
        let decoded = decode_batch(&groups[0]);
        debug_assert_eq!(decoded.data[0], img.data[0], "decode inverts the request encoding");
        self.pool.recycle_payload(BatchPayload::Encoded(groups));
        self.pool.put_u8(img.data);
        self.pool.put_f32(img.labels);
        Ok(())
    }

    /// Pop up to `size` requests, shed the ones that cannot finish in
    /// deadline, and launch the rest as one micro-batch.
    fn dispatch(&mut self, size: usize) -> Result<(), ServeError> {
        let mut batch: Vec<Request> = Vec::with_capacity(size);
        while batch.len() < size {
            match self.queue.pop() {
                Some(r) => batch.push(r),
                None => break,
            }
        }
        let deadline = self.cfg.deadline_ms / 1e3;
        // Shedding latecomers shrinks the batch, which only shortens the
        // service time — so this settles in ≤ batch.len() rounds.
        loop {
            if batch.is_empty() {
                self.hub.set_queue_depth(self.queue.len() as u64);
                return Ok(());
            }
            let service = self.service_secs(batch.len())?;
            let done = self.now + service;
            let mut kept = Vec::with_capacity(batch.len());
            let mut overdue = Vec::new();
            for r in batch.drain(..) {
                if done - r.arrival_secs > deadline {
                    overdue.push(r);
                } else {
                    kept.push(r);
                }
            }
            for r in &overdue {
                self.shed(r.client, ShedReason::DeadlineExceeded)?;
            }
            if overdue.is_empty() {
                let b = kept.len();
                self.encode_dispatch(b)?;
                for r in &kept {
                    self.queue_wait_ns
                        .record(((self.now - r.arrival_secs) * 1e9) as u64);
                }
                self.service_ns.record((service * 1e9) as u64);
                *self.batch_hist.entry(b).or_insert(0) += 1;
                self.hub.record_batch(b as u64);
                self.hub.set_queue_depth(self.queue.len() as u64);
                self.inflight = Some((kept, done));
                return Ok(());
            }
            batch = kept;
        }
    }

    /// The in-flight batch finishes: exact latencies, clients unblock.
    fn complete(&mut self) {
        let (batch, _done) = self.inflight.take().expect("completion without inflight");
        for r in &batch {
            let lat = self.now - r.arrival_secs;
            self.latencies.push(lat);
            self.e2e_ns.record((lat * 1e9) as u64);
            self.completed += 1;
            let c = &mut self.clients[r.client];
            c.waiting = false;
            let t = think_secs(&mut c.rng, self.cfg.think_ms);
            c.next_issue_secs = self.now + t;
        }
        self.last_response = self.now;
        self.push_phase_stats();
    }

    /// Stream the serve-loop quantile tables into the hub so `/metrics`
    /// exposes them as `optorch_phase_seconds{phase,quantile}` gauges.
    fn push_phase_stats(&self) {
        self.hub.update_phase_stats(&[
            PhaseStat::from_histogram("serve-queue-wait".to_string(), &self.queue_wait_ns),
            PhaseStat::from_histogram("serve-service".to_string(), &self.service_ns),
            PhaseStat::from_histogram("serve-e2e".to_string(), &self.e2e_ns),
        ]);
    }

    /// Earliest pending arrival `(time, client)`, if any client can
    /// still issue.
    fn next_arrival(&self) -> Option<(f64, usize)> {
        if self.issued >= self.cfg.requests as u64 {
            return None;
        }
        self.clients
            .iter()
            .enumerate()
            .filter(|(_, c)| !c.waiting)
            .map(|(i, c)| (c.next_issue_secs, i))
            .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)))
    }

    fn run(mut self) -> Result<ServeReport, ServeError> {
        let total = self.cfg.requests as u64;
        while self.completed + self.shed_total() < total {
            let arrival = self.next_arrival();
            let completion = self.inflight.as_ref().map(|(_, t)| *t);
            let decision = if self.inflight.is_none() {
                self.batcher.decide(
                    self.queue.len(),
                    self.queue.oldest_arrival_secs(),
                    self.now,
                    arrival.is_some(),
                )
            } else {
                BatchDecision::Idle
            };
            if let BatchDecision::Dispatch { size } = decision {
                self.dispatch(size)?;
                continue;
            }
            // Pick the earliest timed event; ties resolve completion →
            // arrival → window expiry, so responses free clients before
            // the freed capacity is re-contested.
            let mut next: Option<(f64, Event)> = None;
            let mut consider = |t: Option<f64>, e: Event| {
                if let Some(t) = t {
                    if next.map(|(best, _)| t < best).unwrap_or(true) {
                        next = Some((t, e));
                    }
                }
            };
            consider(completion, Event::Completion);
            consider(arrival.map(|(t, _)| t), Event::Arrival(arrival.map(|(_, i)| i).unwrap_or(0)));
            if let BatchDecision::WaitUntil { at_secs } = decision {
                consider(Some(at_secs), Event::DispatchCheck);
            }
            let Some((t, event)) = next else {
                // No pending events yet unanswered requests would mean a
                // stuck simulation; by construction every issued request
                // is queued (⇒ dispatchable), in flight (⇒ completion
                // pending) or answered, so this cannot happen.
                unreachable!("serve simulation stalled at t={}", self.now);
            };
            self.now = self.now.max(t);
            match event {
                Event::Completion => self.complete(),
                Event::Arrival(client) => self.arrive(client)?,
                Event::DispatchCheck => { /* re-decide next iteration */ }
            }
        }
        self.push_phase_stats();
        self.finish()
    }

    fn finish(mut self) -> Result<ServeReport, ServeError> {
        let elapsed = (self.last_response - self.first_arrival.unwrap_or(0.0)).max(1e-9);
        let mut sorted = self.latencies.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let serve_batch = self.eff_max.max(1);
        let forward_slab = self
            .plan_for(serve_batch)
            .map(|o| o.device_peak_packed())
            .unwrap_or(0);
        // The training twin of the serving plan, for the slab margin the
        // admission controller spends. Planned outside the cache (it is
        // a Train-mode outcome, not a dispatchable plan).
        let train_slab = PlanRequest::for_model(&self.cfg.model, self.cfg.input, self.cfg.classes)
            .batch(serve_batch)
            .run()
            .ok()
            .map(|o| o.device_peak_packed());
        let degradation = match (self.trigger.take(), self.actions.is_empty()) {
            (Some(trigger), false) => {
                let heap_fallback = self
                    .actions
                    .iter()
                    .any(|a| matches!(a, DegradationAction::HeapFallbackArena));
                Some(DegradationReport {
                    trigger,
                    actions: self.actions.clone(),
                    met_budget: !heap_fallback,
                    budget: self.cfg.budget.unwrap_or(0),
                    device_total: forward_slab,
                    predicted_step_secs: None,
                })
            }
            _ => None,
        };
        Ok(ServeReport {
            model: self.cfg.model.clone(),
            requests: self.issued,
            completed: self.completed,
            shed_queue_full: self.shed_queue_full,
            shed_budget: self.shed_budget,
            shed_deadline: self.shed_deadline,
            elapsed_secs: elapsed,
            requests_per_sec: self.completed as f64 / elapsed,
            p50_ms: exact_quantile(&sorted, 0.50) * 1e3,
            p99_ms: exact_quantile(&sorted, 0.99) * 1e3,
            deadline_ms: self.cfg.deadline_ms,
            max_batch_start: self.cfg.max_batch,
            max_batch_final: self.policy_max,
            batch_hist: self.batch_hist.iter().map(|(&s, &n)| (s, n)).collect(),
            cache_hits: self.cache.hits(),
            cache_misses: self.cache.misses(),
            cache_evictions: self.cache.evictions(),
            pool_allocs: self.pool.allocs(),
            pool_reuses: self.pool.reuses(),
            forward_slab_bytes: forward_slab,
            train_slab_bytes: train_slab,
            degradation,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nominal() -> ServeConfig {
        ServeConfig {
            requests: 200,
            clients: 4,
            think_ms: 20.0,
            deadline_ms: 200.0,
            max_batch: 8,
            ..ServeConfig::default_for("resnet18")
        }
    }

    #[test]
    fn nominal_load_completes_everything_without_sheds() {
        let hub = MetricsHub::new();
        let rep = run(&nominal(), &hub).unwrap();
        assert_eq!(rep.requests, 200);
        assert_eq!(rep.completed, 200);
        assert_eq!(rep.shed_total(), 0, "below threshold nothing sheds");
        assert!(rep.p99_ms <= rep.deadline_ms + 1e-9, "deadline honored: {}", rep.p99_ms);
        assert!(rep.requests_per_sec > 0.0);
        assert!(hub.is_ready(), "zero shed rate keeps /readyz green");
        assert_eq!(hub.admitted(), 200);
        assert_eq!(hub.shed(), 0);
        assert!(
            rep.cache_hits > rep.cache_misses,
            "steady state resolves plans from the cache ({} hits / {} misses)",
            rep.cache_hits,
            rep.cache_misses
        );
        assert!(
            rep.pool_reuses > rep.pool_allocs,
            "steady state draws request buffers from the pool ({} reuses / {} allocs)",
            rep.pool_reuses,
            rep.pool_allocs
        );
        assert!(rep.degradation.is_none());
    }

    #[test]
    fn same_seed_is_bit_identical_different_seed_is_not() {
        let a = run(&nominal(), &MetricsHub::new()).unwrap();
        let b = run(&nominal(), &MetricsHub::new()).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        let c = run(
            &ServeConfig { seed: 43, ..nominal() },
            &MetricsHub::new(),
        )
        .unwrap();
        assert_ne!(
            a.to_json().to_string(),
            c.to_json().to_string(),
            "think-time stream actually depends on the seed"
        );
    }

    #[test]
    fn forward_slab_strictly_smaller_than_training_slab() {
        let rep = run(&nominal(), &MetricsHub::new()).unwrap();
        let train = rep.train_slab_bytes.expect("training plan exists");
        assert!(
            rep.forward_slab_bytes < train,
            "forward {} !< train {}",
            rep.forward_slab_bytes,
            train
        );
    }

    #[test]
    fn infeasible_budget_sheds_everything_with_budget_reason() {
        let cfg = ServeConfig {
            budget: Some(1024), // nothing fits 1 KiB
            requests: 20,
            ..nominal()
        };
        let hub = MetricsHub::new();
        let rep = run(&cfg, &hub).unwrap();
        assert_eq!(rep.completed, 0);
        assert_eq!(rep.shed_budget, 20);
        assert!(!hub.is_ready(), "sustained sheds flip /readyz to 503");
    }

    #[test]
    fn overload_walks_the_ladder() {
        // Saturate: many chatty clients, a tiny queue and a deadline the
        // coalesced batches cannot meet, so sheds accumulate fast.
        let cfg = ServeConfig {
            clients: 32,
            requests: 600,
            think_ms: 0.0,
            queue_cap: 2,
            deadline_ms: 0.05,
            max_batch: 16,
            shed_window: 16,
            overload_shed_rate: 0.25,
            ..ServeConfig::default_for("resnet18")
        };
        let rep = run(&cfg, &MetricsHub::new()).unwrap();
        assert!(rep.shed_total() > 0, "overload must shed");
        let deg = rep.degradation.expect("sustained overload walks the ladder");
        assert!(matches!(deg.trigger, DegradeTrigger::Overload { .. }));
        assert!(matches!(
            deg.actions[0],
            DegradationAction::ReducedMaxBatch { from: 16, to: 8 }
        ));
        assert!(rep.max_batch_final < rep.max_batch_start);
    }

    #[test]
    fn config_sources_parse_file_and_overrides() {
        let file = "arch = resnet34\nmax_batch = 4\ndeadline_ms = 12.5\nbudget = 2GiB\n";
        let mut overrides = BTreeMap::new();
        overrides.insert("max_batch".to_string(), "8".to_string());
        let cfg = ServeConfig::from_sources(Some(file), &overrides).unwrap();
        assert_eq!(cfg.model, "resnet34");
        assert_eq!(cfg.max_batch, 8, "override wins over file");
        assert_eq!(cfg.deadline_ms, 12.5);
        assert_eq!(cfg.budget, Some(2 << 30));
        assert!(ServeConfig::from_sources(Some("deadline_ms = 0\n"), &BTreeMap::new()).is_err());
        assert!(ServeConfig::from_sources(Some("budget = nonsense\n"), &BTreeMap::new()).is_err());
    }
}
