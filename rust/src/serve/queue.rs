//! Bounded request queue between admission and the micro-batcher.
//!
//! One [`Request`] is one image awaiting classification. The queue is a
//! plain FIFO with a hard capacity: admission consults
//! [`BoundedQueue::is_full`] *before* enqueueing and sheds with
//! [`ShedReason::QueueFull`](crate::serve::ShedReason::QueueFull) rather
//! than letting the queue grow — bounded memory is the whole point of a
//! serving tier sized to a device budget.

use std::collections::VecDeque;

/// One in-flight inference request (times are virtual-clock seconds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Request {
    /// Global issue order (0-based).
    pub id: u64,
    /// Closed-loop client that issued it (indexes the engine's clients).
    pub client: usize,
    /// Virtual time the request arrived at admission.
    pub arrival_secs: f64,
}

/// FIFO of admitted-but-undispatched requests, capacity fixed at
/// construction.
pub struct BoundedQueue {
    items: VecDeque<Request>,
    capacity: usize,
}

impl BoundedQueue {
    pub fn new(capacity: usize) -> BoundedQueue {
        let capacity = capacity.max(1);
        BoundedQueue { items: VecDeque::with_capacity(capacity), capacity }
    }

    /// Enqueue, or hand the request back when at capacity.
    pub fn push(&mut self, req: Request) -> Result<(), Request> {
        if self.items.len() >= self.capacity {
            return Err(req);
        }
        self.items.push_back(req);
        Ok(())
    }

    pub fn pop(&mut self) -> Option<Request> {
        self.items.pop_front()
    }

    /// Arrival time of the head request (the longest waiter).
    pub fn oldest_arrival_secs(&self) -> Option<f64> {
        self.items.front().map(|r| r.arrival_secs)
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.items.len() >= self.capacity
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, at: f64) -> Request {
        Request { id, client: 0, arrival_secs: at }
    }

    #[test]
    fn fifo_order_and_capacity() {
        let mut q = BoundedQueue::new(2);
        assert!(q.push(req(0, 0.0)).is_ok());
        assert!(q.push(req(1, 0.1)).is_ok());
        assert!(q.is_full());
        let rejected = q.push(req(2, 0.2)).unwrap_err();
        assert_eq!(rejected.id, 2, "overflow hands the request back");
        assert_eq!(q.oldest_arrival_secs(), Some(0.0));
        assert_eq!(q.pop().unwrap().id, 0);
        assert_eq!(q.pop().unwrap().id, 1);
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut q = BoundedQueue::new(0);
        assert_eq!(q.capacity(), 1);
        assert!(q.push(req(0, 0.0)).is_ok());
        assert!(q.push(req(1, 0.0)).is_err());
    }
}
