//! [`ServeReport`]: everything one serving run measured, with the stable
//! JSON and markdown renderers every consumer (CLI, bench, CI gate)
//! shares — the serving twin of `TrainReport`.

use crate::fault::DegradationReport;
use crate::util::bench::fmt_bytes;
use crate::util::json::{arr, n, obj, s, Json};

/// The measured outcome of one closed-loop serving run. All times are
/// virtual-clock (deterministic) except where noted.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub model: String,
    /// Requests issued by the synthetic clients.
    pub requests: u64,
    /// Requests answered inside deadline and budget.
    pub completed: u64,
    pub shed_queue_full: u64,
    pub shed_budget: u64,
    pub shed_deadline: u64,
    /// Virtual seconds from first arrival to last response.
    pub elapsed_secs: f64,
    /// Completed requests per virtual second.
    pub requests_per_sec: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub deadline_ms: f64,
    /// Batch ceiling at start and after any ladder rungs.
    pub max_batch_start: usize,
    pub max_batch_final: usize,
    /// `(batch size, dispatch count)` pairs, ascending by size.
    pub batch_hist: Vec<(usize, u64)>,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    /// Request-buffer pool counters (steady state: reuses ≫ allocs).
    pub pool_allocs: u64,
    pub pool_reuses: u64,
    /// Packed forward-only slab of the largest admitted batch.
    pub forward_slab_bytes: u64,
    /// Packed training slab of the same arch/batch, for the margin the
    /// admission controller spends (`None` when training is infeasible
    /// to plan, e.g. zero-layer archs).
    pub train_slab_bytes: Option<u64>,
    /// The overload episode, when the ladder was walked.
    pub degradation: Option<DegradationReport>,
}

impl ServeReport {
    pub fn shed_total(&self) -> u64 {
        self.shed_queue_full + self.shed_budget + self.shed_deadline
    }

    /// Stable JSON rendering (same builder conventions as
    /// `PlanOutcome::to_json`): same report, same bytes.
    pub fn to_json(&self) -> Json {
        let shed = obj(vec![
            ("queue-full", n(self.shed_queue_full as f64)),
            ("budget-exceeded", n(self.shed_budget as f64)),
            ("deadline-exceeded", n(self.shed_deadline as f64)),
            ("total", n(self.shed_total() as f64)),
        ]);
        let batches = arr(
            self.batch_hist
                .iter()
                .map(|&(size, count)| {
                    obj(vec![("size", n(size as f64)), ("count", n(count as f64))])
                })
                .collect(),
        );
        let cache = obj(vec![
            ("hits", n(self.cache_hits as f64)),
            ("misses", n(self.cache_misses as f64)),
            ("evictions", n(self.cache_evictions as f64)),
        ]);
        let pool = obj(vec![
            ("allocs", n(self.pool_allocs as f64)),
            ("reuses", n(self.pool_reuses as f64)),
        ]);
        let mut fields = vec![
            ("model", s(&self.model)),
            ("requests", n(self.requests as f64)),
            ("completed", n(self.completed as f64)),
            ("shed", shed),
            ("elapsed_secs", n(self.elapsed_secs)),
            ("requests_per_sec", n(self.requests_per_sec)),
            ("p50_ms", n(self.p50_ms)),
            ("p99_ms", n(self.p99_ms)),
            ("deadline_ms", n(self.deadline_ms)),
            ("max_batch_start", n(self.max_batch_start as f64)),
            ("max_batch_final", n(self.max_batch_final as f64)),
            ("batches", batches),
            ("plan_cache", cache),
            ("buffer_pool", pool),
            ("forward_slab_bytes", n(self.forward_slab_bytes as f64)),
        ];
        if let Some(t) = self.train_slab_bytes {
            fields.push(("train_slab_bytes", n(t as f64)));
        }
        if let Some(d) = &self.degradation {
            fields.push(("degradation", d.to_json()));
        }
        obj(fields)
    }

    /// Markdown summary (the `optorch serve` stdout block).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("### serve: {}\n\n", self.model));
        out.push_str(&format!(
            "- throughput: {:.1} req/s over {:.3}s ({} completed of {} issued)\n",
            self.requests_per_sec, self.elapsed_secs, self.completed, self.requests
        ));
        out.push_str(&format!(
            "- latency: p50 {:.2} ms, p99 {:.2} ms (deadline {:.0} ms)\n",
            self.p50_ms, self.p99_ms, self.deadline_ms
        ));
        out.push_str(&format!(
            "- shed: {} total (queue-full {}, budget-exceeded {}, deadline-exceeded {})\n",
            self.shed_total(),
            self.shed_queue_full,
            self.shed_budget,
            self.shed_deadline
        ));
        let batches: Vec<String> = self
            .batch_hist
            .iter()
            .map(|&(size, count)| format!("{size}×{count}"))
            .collect();
        out.push_str(&format!(
            "- batches (size×count): {} — max batch {} → {}\n",
            if batches.is_empty() { "none".to_string() } else { batches.join(", ") },
            self.max_batch_start,
            self.max_batch_final
        ));
        out.push_str(&format!(
            "- plan cache: {} hits / {} misses / {} evictions; buffer pool: {} allocs / {} reuses\n",
            self.cache_hits,
            self.cache_misses,
            self.cache_evictions,
            self.pool_allocs,
            self.pool_reuses
        ));
        match self.train_slab_bytes {
            Some(t) if t > 0 => out.push_str(&format!(
                "- forward-only slab {} vs training slab {} ({:.1}% of training)\n",
                fmt_bytes(self.forward_slab_bytes),
                fmt_bytes(t),
                self.forward_slab_bytes as f64 / t as f64 * 100.0
            )),
            _ => out.push_str(&format!(
                "- forward-only slab {}\n",
                fmt_bytes(self.forward_slab_bytes)
            )),
        }
        if let Some(d) = &self.degradation {
            out.push_str(&format!("- {}\n", d.to_markdown()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ServeReport {
        ServeReport {
            model: "resnet18".to_string(),
            requests: 100,
            completed: 92,
            shed_queue_full: 5,
            shed_budget: 0,
            shed_deadline: 3,
            elapsed_secs: 2.5,
            requests_per_sec: 36.8,
            p50_ms: 4.2,
            p99_ms: 11.9,
            deadline_ms: 25.0,
            max_batch_start: 16,
            max_batch_final: 8,
            batch_hist: vec![(4, 3), (8, 10)],
            cache_hits: 11,
            cache_misses: 2,
            cache_evictions: 0,
            pool_allocs: 4,
            pool_reuses: 9,
            forward_slab_bytes: 3 << 20,
            train_slab_bytes: Some(12 << 20),
            degradation: None,
        }
    }

    #[test]
    fn json_is_stable_and_reparses() {
        let j = sample().to_json();
        assert_eq!(j.get("model").unwrap().as_str().unwrap(), "resnet18");
        assert_eq!(j.get("completed").unwrap().as_f64().unwrap(), 92.0);
        let shed = j.get("shed").unwrap();
        assert_eq!(shed.get("total").unwrap().as_f64().unwrap(), 8.0);
        assert_eq!(shed.get("queue-full").unwrap().as_f64().unwrap(), 5.0);
        let batches = j.get("batches").unwrap().as_arr().unwrap();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[1].get("size").unwrap().as_f64().unwrap(), 8.0);
        let text = j.to_string();
        assert_eq!(text, sample().to_json().to_string(), "deterministic bytes");
        crate::util::json::Json::parse(&text).unwrap();
    }

    #[test]
    fn markdown_names_the_load_bearing_numbers() {
        let md = sample().to_markdown();
        assert!(md.contains("36.8 req/s"), "{md}");
        assert!(md.contains("p99 11.90 ms"), "{md}");
        assert!(md.contains("queue-full 5"), "{md}");
        assert!(md.contains("4×3, 8×10"), "{md}");
        assert!(md.contains("max batch 16 → 8"), "{md}");
        assert!(md.contains("25.0% of training"), "{md}");
    }
}
