//! Trace events, per-thread buffers and the [`Tracer`] handle.
//!
//! The hot-path contract: recording an event is a bounds check + a write
//! into a thread-owned, pre-allocated `Vec` — no locks, no allocation, no
//! shared state. Each thread of the pipeline (loader planner, encode
//! workers, sequencer, the train-step loop, the offload engine's link
//! replay) owns a [`ThreadTracer`]; its buffer is handed to the shared
//! collector exactly once, when the thread finishes (drop or
//! [`ThreadTracer::finish`]). A buffer that fills up *drops* further
//! events and counts them — tracing never grows memory or stalls the
//! pipeline it is observing.
//!
//! A disabled tracer ([`Tracer::disabled`]) hands out `ThreadTracer`s
//! whose every method is a single branch on an `Option` — the "tracing
//! off" configuration costs nothing measurable (gated by
//! `benches/trace_overhead.rs`).

use std::borrow::Cow;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::trace::export::TraceLog;

/// Default per-thread event capacity (events, not bytes). At ~64 B/event
/// this bounds a track at ~2 MiB.
pub const DEFAULT_TRACK_CAPACITY: usize = 32 * 1024;

/// What one [`TraceEvent`] records.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EventKind {
    /// A completed span: the event's `ts_ns` is the span *start*, `dur_ns`
    /// its length (Chrome `ph: "X"`).
    Span { dur_ns: u64 },
    /// A point-in-time marker (Chrome `ph: "i"`).
    Instant,
    /// A sampled counter value (Chrome `ph: "C"`).
    Counter { value: f64 },
}

/// One recorded event. Steady-state events carry only `'static` names and
/// numeric args; `label` is reserved for rare-path annotations (fault
/// specs, degradation rungs) where an allocation is acceptable.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub name: Cow<'static, str>,
    /// Category (Chrome `cat`): `loader`, `offload`, `step`, `fault`, …
    pub cat: &'static str,
    /// Nanoseconds since the tracer's origin ([`Tracer`] creation).
    pub ts_ns: u64,
    pub kind: EventKind,
    /// Optional numeric argument (rendered into Chrome `args`).
    pub arg: Option<(&'static str, f64)>,
    /// Optional string annotation (rare path only).
    pub label: Option<String>,
}

/// One thread's finished event buffer, as handed to the collector.
#[derive(Clone, Debug)]
pub struct Track {
    /// Display name (`loader/worker-0`, `offload/link`, `train/step`, …).
    pub name: String,
    /// Collector-assigned registration sequence; orders same-named tracks
    /// (a respawned worker reuses its predecessor's name) causally.
    pub seq: u64,
    /// Events in push order (per-thread program order).
    pub events: Vec<TraceEvent>,
    /// Events discarded because the buffer was full.
    pub dropped: u64,
}

#[derive(Debug)]
struct Shared {
    start: Instant,
    capacity: usize,
    next_seq: AtomicU64,
    done: Mutex<Vec<Track>>,
}

/// The cheap-to-clone tracing handle threaded through the pipeline. A
/// disabled tracer is a `None` and costs one branch per would-be event.
#[derive(Clone, Debug)]
pub struct Tracer {
    shared: Option<Arc<Shared>>,
}

impl Tracer {
    /// An enabled tracer with the default per-thread capacity.
    pub fn enabled() -> Tracer {
        Tracer::with_capacity(DEFAULT_TRACK_CAPACITY)
    }

    /// An enabled tracer with an explicit per-thread event capacity
    /// (clamped to ≥ 16 so guards and flushes always have room to record).
    pub fn with_capacity(capacity: usize) -> Tracer {
        Tracer {
            shared: Some(Arc::new(Shared {
                start: Instant::now(),
                capacity: capacity.max(16),
                next_seq: AtomicU64::new(0),
                done: Mutex::new(Vec::new()),
            })),
        }
    }

    /// The no-op tracer: every derived [`ThreadTracer`] is a single-branch
    /// stub and [`Tracer::drain`] returns an empty log.
    pub fn disabled() -> Tracer {
        Tracer { shared: None }
    }

    pub fn is_enabled(&self) -> bool {
        self.shared.is_some()
    }

    /// Register a new per-thread buffer. The returned [`ThreadTracer`] is
    /// `Send` and owned by exactly one thread; its events surface in the
    /// drained log once the thread drops (or `finish`es) it.
    pub fn thread(&self, name: impl Into<String>) -> ThreadTracer {
        match &self.shared {
            None => ThreadTracer {
                shared: None,
                name: String::new(),
                seq: 0,
                buf: Vec::new(),
                dropped: 0,
            },
            Some(sh) => ThreadTracer {
                seq: sh.next_seq.fetch_add(1, Ordering::Relaxed),
                shared: Some(sh.clone()),
                name: name.into(),
                buf: Vec::with_capacity(sh.capacity),
                dropped: 0,
            },
        }
    }

    /// Nanoseconds since this tracer's origin (0 when disabled).
    pub fn now_ns(&self) -> u64 {
        match &self.shared {
            None => 0,
            Some(sh) => sh.start.elapsed().as_nanos() as u64,
        }
    }

    /// Collect every finished track into an ordered [`TraceLog`]. Tracks
    /// still owned by live threads are not included — finish/drop their
    /// [`ThreadTracer`]s first (the loader and engine do this when they
    /// wind down).
    pub fn drain(&self) -> TraceLog {
        let tracks = match &self.shared {
            None => Vec::new(),
            Some(sh) => std::mem::take(&mut *sh.done.lock().unwrap_or_else(|e| e.into_inner())),
        };
        TraceLog::from_tracks(tracks)
    }
}

/// A thread-owned event buffer. All recording methods are no-ops (one
/// branch) when the parent tracer is disabled, and never allocate or lock
/// when it is enabled.
#[derive(Debug)]
pub struct ThreadTracer {
    shared: Option<Arc<Shared>>,
    name: String,
    seq: u64,
    buf: Vec<TraceEvent>,
    dropped: u64,
}

impl ThreadTracer {
    pub fn is_enabled(&self) -> bool {
        self.shared.is_some()
    }

    /// Nanoseconds since the tracer origin (0 when disabled).
    #[inline]
    pub fn now_ns(&self) -> u64 {
        match &self.shared {
            None => 0,
            Some(sh) => sh.start.elapsed().as_nanos() as u64,
        }
    }

    /// Start a span: returns the begin timestamp to pass to
    /// [`ThreadTracer::end_span`]. Spans nest by call discipline — end the
    /// inner one before the outer (verified by `tests/prop_trace.rs`).
    #[inline]
    pub fn begin(&self) -> u64 {
        self.now_ns()
    }

    /// Close a span begun at `t0`.
    #[inline]
    pub fn end_span(&mut self, name: &'static str, cat: &'static str, t0: u64) {
        self.end_span_arg(name, cat, t0, None);
    }

    /// Close a span begun at `t0`, attaching one numeric argument.
    #[inline]
    pub fn end_span_arg(
        &mut self,
        name: &'static str,
        cat: &'static str,
        t0: u64,
        arg: Option<(&'static str, f64)>,
    ) {
        if self.shared.is_none() {
            return;
        }
        let now = self.now_ns();
        self.push(TraceEvent {
            name: Cow::Borrowed(name),
            cat,
            ts_ns: t0,
            kind: EventKind::Span { dur_ns: now.saturating_sub(t0) },
            arg,
            label: None,
        });
    }

    /// Run `f` inside a span.
    #[inline]
    pub fn with_span<R>(
        &mut self,
        name: &'static str,
        cat: &'static str,
        f: impl FnOnce(&mut ThreadTracer) -> R,
    ) -> R {
        let t0 = self.begin();
        let r = f(self);
        self.end_span(name, cat, t0);
        r
    }

    /// Record an instant event.
    #[inline]
    pub fn instant(&mut self, name: &'static str, cat: &'static str) {
        self.instant_arg(name, cat, None);
    }

    /// Record an instant event with one numeric argument.
    #[inline]
    pub fn instant_arg(
        &mut self,
        name: &'static str,
        cat: &'static str,
        arg: Option<(&'static str, f64)>,
    ) {
        if self.shared.is_none() {
            return;
        }
        let ts = self.now_ns();
        self.push(TraceEvent {
            name: Cow::Borrowed(name),
            cat,
            ts_ns: ts,
            kind: EventKind::Instant,
            arg,
            label: None,
        });
    }

    /// Record an instant carrying a string annotation (allocates — rare
    /// path only: fault firings, degradation rungs).
    pub fn instant_label(&mut self, name: &'static str, cat: &'static str, label: &str) {
        if self.shared.is_none() {
            return;
        }
        let ts = self.now_ns();
        self.push(TraceEvent {
            name: Cow::Borrowed(name),
            cat,
            ts_ns: ts,
            kind: EventKind::Instant,
            arg: None,
            label: Some(label.to_string()),
        });
    }

    /// Record a counter sample.
    #[inline]
    pub fn counter(&mut self, name: &'static str, cat: &'static str, value: f64) {
        if self.shared.is_none() {
            return;
        }
        let ts = self.now_ns();
        self.push(TraceEvent {
            name: Cow::Borrowed(name),
            cat,
            ts_ns: ts,
            kind: EventKind::Counter { value },
            arg: None,
            label: None,
        });
    }

    #[inline]
    fn push(&mut self, ev: TraceEvent) {
        if self.buf.len() < self.buf.capacity() {
            self.buf.push(ev);
        } else {
            self.dropped += 1;
        }
    }

    /// Buffered event count (0 when disabled).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events discarded because the buffer filled.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// The fixed buffer capacity (never grows after construction).
    pub fn capacity(&self) -> usize {
        self.buf.capacity()
    }

    /// Hand the buffer to the collector now (drop does the same).
    pub fn finish(self) {}
}

impl Drop for ThreadTracer {
    fn drop(&mut self) {
        if let Some(sh) = self.shared.take() {
            let track = Track {
                name: std::mem::take(&mut self.name),
                seq: self.seq,
                events: std::mem::take(&mut self.buf),
                dropped: self.dropped,
            };
            sh.done.lock().unwrap_or_else(|e| e.into_inner()).push(track);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_is_inert() {
        let tr = Tracer::disabled();
        assert!(!tr.is_enabled());
        let mut t = tr.thread("x");
        assert!(!t.is_enabled());
        let t0 = t.begin();
        t.end_span("a", "c", t0);
        t.instant("b", "c");
        t.counter("n", "c", 1.0);
        assert_eq!(t.len(), 0);
        assert_eq!(t.capacity(), 0, "disabled threads must not allocate");
        drop(t);
        assert_eq!(tr.drain().tracks.len(), 0);
    }

    #[test]
    fn spans_and_instants_surface_after_finish() {
        let tr = Tracer::with_capacity(64);
        let mut t = tr.thread("worker");
        let outer = t.begin();
        t.with_span("inner", "test", |t| t.instant_arg("tick", "test", Some(("step", 3.0))));
        t.end_span_arg("outer", "test", outer, Some(("bytes", 42.0)));
        assert!(tr.drain().tracks.is_empty(), "live threads are not drained");
        t.finish();
        let log = tr.drain();
        assert_eq!(log.tracks.len(), 1);
        let track = &log.tracks[0];
        assert_eq!(track.name, "worker");
        let names: Vec<&str> = track.events.iter().map(|e| e.name.as_ref()).collect();
        assert_eq!(names, ["tick", "inner", "outer"], "push order = per-thread program order");
        match track.events[2].kind {
            EventKind::Span { dur_ns } => assert!(dur_ns > 0),
            ref k => panic!("outer should be a span, got {k:?}"),
        }
        assert_eq!(track.events[2].arg, Some(("bytes", 42.0)));
        // a second drain is empty — the log moved out
        assert!(tr.drain().tracks.is_empty());
    }

    #[test]
    fn full_buffer_drops_instead_of_growing() {
        let tr = Tracer::with_capacity(16);
        let mut t = tr.thread("tight");
        let cap = t.capacity();
        for _ in 0..cap + 10 {
            t.instant("e", "test");
        }
        assert_eq!(t.len(), cap);
        assert_eq!(t.dropped(), 10);
        assert_eq!(t.capacity(), cap, "buffer must never reallocate");
        t.finish();
        let log = tr.drain();
        assert_eq!(log.tracks[0].dropped, 10);
    }

    #[test]
    fn timestamps_are_monotonic_per_thread() {
        let tr = Tracer::enabled();
        let mut t = tr.thread("mono");
        for _ in 0..100 {
            t.instant("tick", "test");
        }
        t.finish();
        let log = tr.drain();
        let ts: Vec<u64> = log.tracks[0].events.iter().map(|e| e.ts_ns).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn respawned_same_name_tracks_are_ordered_by_seq() {
        let tr = Tracer::with_capacity(16);
        let mut a = tr.thread("loader/worker-0");
        a.instant("first-life", "test");
        a.finish();
        let mut b = tr.thread("loader/worker-0");
        b.instant("second-life", "test");
        b.finish();
        let log = tr.drain();
        assert_eq!(log.tracks.len(), 2);
        assert!(log.tracks[0].seq < log.tracks[1].seq);
        assert_eq!(log.tracks[0].events[0].name, "first-life");
    }
}
