//! Draining, aggregation and export of trace buffers: the ordered
//! [`TraceLog`], its Chrome trace-event JSON rendering (loadable in
//! Perfetto / `chrome://tracing`), per-phase latency histograms, the
//! unified [`CounterRegistry`], and the predicted-vs-observed
//! [`DriftReport`].

use std::collections::BTreeMap;

use crate::metrics::Histogram;
use crate::trace::event::{EventKind, Track};
use crate::util::json::{arr, n, obj, s, Json};

/// Every finished track, ordered deterministically: by track name, then by
/// registration sequence (so a respawned worker's two lives render as two
/// causally ordered tracks under the same name). Event order inside a
/// track is per-thread program order.
#[derive(Clone, Debug, Default)]
pub struct TraceLog {
    pub tracks: Vec<Track>,
}

impl TraceLog {
    pub fn from_tracks(mut tracks: Vec<Track>) -> TraceLog {
        tracks.sort_by(|a, b| a.name.cmp(&b.name).then(a.seq.cmp(&b.seq)));
        TraceLog { tracks }
    }

    /// Total recorded events across all tracks.
    pub fn event_count(&self) -> usize {
        self.tracks.iter().map(|t| t.events.len()).sum()
    }

    /// Total events dropped by full buffers across all tracks.
    pub fn dropped(&self) -> u64 {
        self.tracks.iter().map(|t| t.dropped).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.tracks.is_empty()
    }

    /// Render the log as a Chrome trace-event document: one `tid` per
    /// track (named via `thread_name` metadata), `pid` 0, timestamps in
    /// microseconds. Spans are complete (`ph: "X"`) events, instants
    /// thread-scoped `"i"`, counters `"C"`.
    pub fn to_chrome_json(&self) -> Json {
        let mut events: Vec<Json> = Vec::with_capacity(self.event_count() + self.tracks.len());
        for (tid, track) in self.tracks.iter().enumerate() {
            let tid_n = tid as f64;
            events.push(obj(vec![
                ("ph", s("M")),
                ("name", s("thread_name")),
                ("pid", n(0.0)),
                ("tid", n(tid_n)),
                ("args", obj(vec![("name", s(&track.name))])),
            ]));
            for ev in &track.events {
                let mut args: Vec<(&str, Json)> = Vec::new();
                if let Some((k, v)) = ev.arg {
                    args.push((k, n(v)));
                }
                if let Some(label) = &ev.label {
                    args.push(("label", s(label)));
                }
                let mut fields: Vec<(&str, Json)> = vec![
                    ("name", s(ev.name.as_ref())),
                    ("cat", s(ev.cat)),
                    ("pid", n(0.0)),
                    ("tid", n(tid_n)),
                    ("ts", n(ev.ts_ns as f64 / 1e3)),
                ];
                match ev.kind {
                    EventKind::Span { dur_ns } => {
                        fields.push(("ph", s("X")));
                        fields.push(("dur", n(dur_ns as f64 / 1e3)));
                    }
                    EventKind::Instant => {
                        fields.push(("ph", s("i")));
                        fields.push(("s", s("t")));
                    }
                    EventKind::Counter { value } => {
                        fields.push(("ph", s("C")));
                        args.push((ev.name.as_ref(), n(value)));
                    }
                }
                if !args.is_empty() {
                    fields.push(("args", Json::Obj(
                        args.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
                    )));
                }
                events.push(Json::Obj(
                    fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
                ));
            }
        }
        obj(vec![
            ("traceEvents", arr(events)),
            ("displayTimeUnit", s("ms")),
        ])
    }

    /// Write the Chrome trace-event document to `path`.
    pub fn write_chrome(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_chrome_json().to_string())
    }

    /// Span durations aggregated per span name into log2 histograms
    /// (nanosecond samples), deterministically ordered by name.
    pub fn phase_histograms(&self) -> BTreeMap<String, Histogram> {
        let mut map: BTreeMap<String, Histogram> = BTreeMap::new();
        for track in &self.tracks {
            for ev in &track.events {
                if let EventKind::Span { dur_ns } = ev.kind {
                    map.entry(ev.name.to_string()).or_default().record(dur_ns);
                }
            }
        }
        map
    }

    /// Per-phase latency quantiles for report rendering.
    pub fn phase_stats(&self) -> Vec<PhaseStat> {
        self.phase_histograms()
            .into_iter()
            .map(|(name, h)| PhaseStat::from_histogram(name, &h))
            .collect()
    }
}

/// p50/p95/p99 wall time of one span phase, in seconds.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseStat {
    pub name: String,
    pub count: u64,
    pub p50_secs: f64,
    pub p95_secs: f64,
    pub p99_secs: f64,
}

impl PhaseStat {
    pub fn from_histogram(name: String, h: &Histogram) -> PhaseStat {
        PhaseStat {
            name,
            count: h.count(),
            p50_secs: h.p50() as f64 / 1e9,
            p95_secs: h.p95() as f64 / 1e9,
            p99_secs: h.p99() as f64 / 1e9,
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", s(&self.name)),
            ("count", n(self.count as f64)),
            ("p50_secs", n(self.p50_secs)),
            ("p95_secs", n(self.p95_secs)),
            ("p99_secs", n(self.p99_secs)),
        ])
    }
}

/// The unified named-counter registry: one deterministic home for the
/// pipeline's previously ad-hoc counters (`pool_allocs`/`pool_reuses`,
/// `corruptions_detected`, `link_faults`/`link_retries`, …) plus the
/// tracer's own bookkeeping (`trace_events`, `trace_dropped`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CounterRegistry {
    counters: BTreeMap<String, u64>,
}

impl CounterRegistry {
    pub fn new() -> CounterRegistry {
        CounterRegistry::default()
    }

    /// Set `name` to `value` (overwrites).
    pub fn set(&mut self, name: &str, value: u64) {
        self.counters.insert(name.to_string(), value);
    }

    /// Add `value` to `name` (0-initialized).
    pub fn add(&mut self, name: &str, value: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += value;
    }

    pub fn get(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty()
    }

    pub fn len(&self) -> usize {
        self.counters.len()
    }

    /// Name-ordered iteration (BTreeMap order, so rendering is stable).
    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, &v)| (k.as_str(), v))
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(self.counters.iter().map(|(k, &v)| (k.clone(), n(v as f64))).collect())
    }
}

/// Cost-model error: the facade's `predicted_step_secs` against the
/// per-step spans a real (or replayed) run observed.
#[derive(Clone, Debug, PartialEq)]
pub struct DriftReport {
    /// The overlap model's prediction for one train step.
    pub predicted_step_secs: f64,
    /// Mean observed `train-step` span duration.
    pub observed_mean_secs: f64,
    pub observed_p50_secs: f64,
    pub observed_p99_secs: f64,
    /// Observed steps the comparison covers.
    pub steps: u64,
}

impl DriftReport {
    /// Compare a prediction against an observed step histogram
    /// (nanosecond samples). `None` when nothing was observed.
    pub fn from_observed(predicted_step_secs: f64, observed: &Histogram) -> Option<DriftReport> {
        if observed.is_empty() {
            return None;
        }
        Some(DriftReport {
            predicted_step_secs,
            observed_mean_secs: observed.mean() / 1e9,
            observed_p50_secs: observed.p50() as f64 / 1e9,
            observed_p99_secs: observed.p99() as f64 / 1e9,
            steps: observed.count(),
        })
    }

    /// Signed model error in seconds (positive = the model was optimistic).
    pub fn abs_err_secs(&self) -> f64 {
        self.observed_mean_secs - self.predicted_step_secs
    }

    /// Relative model error against the prediction (infinite when the
    /// model predicted a zero-cost step but one was observed).
    pub fn rel_err(&self) -> f64 {
        if self.predicted_step_secs > 0.0 {
            self.abs_err_secs() / self.predicted_step_secs
        } else if self.observed_mean_secs > 0.0 {
            f64::INFINITY
        } else {
            0.0
        }
    }

    /// One-line markdown rendering for reports.
    pub fn to_markdown_line(&self) -> String {
        format!(
            "drift: predicted {:.6} s/step vs observed {:.6} s/step mean \
             ({:+.1}% over {} steps; observed p50 {:.6} s, p99 {:.6} s)",
            self.predicted_step_secs,
            self.observed_mean_secs,
            self.rel_err() * 100.0,
            self.steps,
            self.observed_p50_secs,
            self.observed_p99_secs,
        )
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("predicted_step_secs", n(self.predicted_step_secs)),
            ("observed_mean_secs", n(self.observed_mean_secs)),
            ("observed_p50_secs", n(self.observed_p50_secs)),
            ("observed_p99_secs", n(self.observed_p99_secs)),
            ("steps", n(self.steps as f64)),
            ("abs_err_secs", n(self.abs_err_secs())),
            ("rel_err", n(self.rel_err())),
        ])
    }
}

/// Extract an observed-duration histogram (nanosecond samples) for the
/// named span from a Chrome trace-event document (`plan --drift FILE`
/// reads a `train --trace` export back through this).
pub fn observed_span_histogram(doc: &Json, span_name: &str) -> Histogram {
    let mut h = Histogram::new();
    let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap_or(&[]);
    for ev in events {
        let is_span = ev.get("ph").and_then(Json::as_str) == Some("X");
        let named = ev.get("name").and_then(Json::as_str) == Some(span_name);
        if is_span && named {
            if let Some(dur_us) = ev.get("dur").and_then(Json::as_f64) {
                h.record((dur_us * 1e3).max(0.0) as u64);
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::event::Tracer;

    fn sample_log() -> TraceLog {
        let tr = Tracer::with_capacity(64);
        let mut a = tr.thread("loader/worker-1");
        let mut b = tr.thread("loader/worker-0");
        let t0 = a.begin();
        a.end_span_arg("produce", "loader", t0, Some(("step", 0.0)));
        a.instant("corruption-reencode", "fault");
        let t0 = b.begin();
        b.end_span("produce", "loader", t0);
        b.counter("seq_depth", "loader", 3.0);
        a.finish();
        b.finish();
        tr.drain()
    }

    #[test]
    fn drain_orders_tracks_by_name() {
        let log = sample_log();
        let names: Vec<&str> = log.tracks.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names, ["loader/worker-0", "loader/worker-1"]);
        assert_eq!(log.event_count(), 4);
        assert_eq!(log.dropped(), 0);
    }

    #[test]
    fn chrome_export_is_valid_and_carries_tracks() {
        let log = sample_log();
        let text = log.to_chrome_json().to_string();
        let doc = Json::parse(&text).expect("export must be valid JSON");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 thread_name metadata records + 4 events
        assert_eq!(events.len(), 6);
        let thread_names: Vec<&str> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .map(|e| e.get("args").unwrap().get("name").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(thread_names, ["loader/worker-0", "loader/worker-1"]);
        let span = events
            .iter()
            .find(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .unwrap();
        assert!(span.get("dur").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(span.get("cat").unwrap().as_str().unwrap(), "loader");
        let counter = events
            .iter()
            .find(|e| e.get("ph").and_then(Json::as_str) == Some("C"))
            .unwrap();
        assert_eq!(
            counter.get("args").unwrap().get("seq_depth").unwrap().as_f64(),
            Some(3.0)
        );
    }

    #[test]
    fn phase_stats_aggregate_across_tracks() {
        let log = sample_log();
        let stats = log.phase_stats();
        assert_eq!(stats.len(), 1, "both produce spans fold into one phase");
        assert_eq!(stats[0].name, "produce");
        assert_eq!(stats[0].count, 2);
        assert!(stats[0].p99_secs >= stats[0].p50_secs);
    }

    #[test]
    fn counter_registry_is_ordered_and_additive() {
        let mut reg = CounterRegistry::new();
        reg.set("pool_allocs", 7);
        reg.add("link_retries", 2);
        reg.add("link_retries", 3);
        assert_eq!(reg.get("link_retries"), 5);
        assert_eq!(reg.get("absent"), 0);
        let keys: Vec<&str> = reg.iter().map(|(k, _)| k).collect();
        assert_eq!(keys, ["link_retries", "pool_allocs"], "BTreeMap order");
        assert_eq!(
            reg.to_json().to_string(),
            r#"{"link_retries":5,"pool_allocs":7}"#
        );
    }

    #[test]
    fn drift_report_math() {
        let mut h = Histogram::new();
        for _ in 0..10 {
            h.record(2_000_000_000); // 2 s steps
        }
        let d = DriftReport::from_observed(1.0, &h).unwrap();
        assert_eq!(d.steps, 10);
        assert!((d.abs_err_secs() - 1.0).abs() < 0.5, "{}", d.abs_err_secs());
        assert!(d.rel_err() > 0.0);
        let line = d.to_markdown_line();
        assert!(line.starts_with("drift: predicted 1.0"), "{line}");
        assert!(DriftReport::from_observed(1.0, &Histogram::new()).is_none());
        let zero = DriftReport {
            predicted_step_secs: 0.0,
            observed_mean_secs: 0.0,
            observed_p50_secs: 0.0,
            observed_p99_secs: 0.0,
            steps: 1,
        };
        assert_eq!(zero.rel_err(), 0.0);
    }

    #[test]
    fn observed_histogram_reads_chrome_export_back() {
        let tr = Tracer::with_capacity(64);
        let mut t = tr.thread("train/step");
        for _ in 0..4 {
            let t0 = t.begin();
            t.end_span("train-step", "step", t0);
        }
        t.instant("not-a-span", "step");
        t.finish();
        let doc = tr.drain().to_chrome_json();
        let text = doc.to_string();
        let parsed = Json::parse(&text).unwrap();
        let h = observed_span_histogram(&parsed, "train-step");
        assert_eq!(h.count(), 4);
        assert_eq!(observed_span_histogram(&parsed, "missing").count(), 0);
    }
}
