//! Structured tracing & profiling: the observability layer over the whole
//! pipeline.
//!
//! Every planning decision in this crate rests on a *predicted* cost model
//! (checkpoint recompute FLOPs, double-buffered link transfers, stall
//! estimates); this module records what actually happened at event
//! granularity so model error becomes measurable (MONeT, Shah et al. 2020,
//! makes the case that offload planning is only as good as its measured
//! per-operator costs). Three layers:
//!
//! * [`event`] — the recording half: a cheap-to-clone [`Tracer`] handle
//!   hands each pipeline thread (loader planner / encode workers /
//!   sequencer, the train-step loop, the offload engine's link replay) an
//!   owned [`ThreadTracer`] buffer. Recording a span/instant/counter is a
//!   bounds check and a write into a pre-allocated `Vec` — no locks, no
//!   allocation on the hot path; full buffers drop (and count) rather than
//!   grow. A [`Tracer::disabled`] handle reduces every call to one branch.
//! * [`export`] — the reporting half: [`Tracer::drain`] collects finished
//!   buffers into a deterministically ordered [`TraceLog`], rendered as
//!   Chrome trace-event JSON (`train --trace out.json`, loadable in
//!   Perfetto / `chrome://tracing` with one named track per
//!   worker/link/step), folded into per-phase p50/p95/p99 latency
//!   histograms ([`PhaseStat`], shared [`crate::metrics::Histogram`]
//!   buckets), and absorbed into the unified [`CounterRegistry`].
//! * [`DriftReport`] — the feedback loop: the facade's
//!   `predicted_step_secs` compared against observed `train-step` spans
//!   (`TrainReport.drift`, `plan --drift FILE`), so cost-model error is a
//!   first-class number instead of an invisible assumption.

pub mod event;
pub mod export;

pub use event::{
    EventKind, ThreadTracer, TraceEvent, Tracer, Track, DEFAULT_TRACK_CAPACITY,
};
pub use export::{
    observed_span_histogram, CounterRegistry, DriftReport, PhaseStat, TraceLog,
};
