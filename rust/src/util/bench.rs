//! Tiny benchmark harness (criterion substitute — see DESIGN.md §5).
//!
//! `cargo bench` runs each `rust/benches/*.rs` with `harness = false`; those
//! binaries use this module for warmup, repeated timing, and robust
//! statistics, and print paper-style tables for the figure reproductions.

use std::time::{Duration, Instant};

/// Timing statistics over repeated runs.
#[derive(Clone, Debug)]
pub struct Stats {
    pub n: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub stddev_ns: f64,
}

impl Stats {
    pub fn from_samples(mut ns: Vec<f64>) -> Stats {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let median = if n % 2 == 1 {
            ns[n / 2]
        } else {
            0.5 * (ns[n / 2 - 1] + ns[n / 2])
        };
        let var = ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            n,
            mean_ns: mean,
            median_ns: median,
            min_ns: ns[0],
            max_ns: ns[n - 1],
            stddev_ns: var.sqrt(),
        }
    }

    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }
}

/// Human-readable duration (ns → µs → ms → s).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Human-readable byte count.
pub fn fmt_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let b = b as f64;
    if b < K {
        format!("{b:.0} B")
    } else if b < K * K {
        format!("{:.1} KiB", b / K)
    } else if b < K * K * K {
        format!("{:.1} MiB", b / (K * K))
    } else {
        format!("{:.2} GiB", b / (K * K * K))
    }
}

/// Benchmark a closure: `warmup` untimed runs then `iters` timed runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    Stats::from_samples(samples)
}

/// Auto-calibrating variant: picks an iteration count that targets
/// `target_total` of measurement time (like criterion's auto mode).
pub fn bench_auto<F: FnMut()>(target_total: Duration, mut f: F) -> Stats {
    // One probe run to size the loop.
    let t0 = Instant::now();
    f();
    let probe = t0.elapsed().as_nanos().max(1) as f64;
    let iters = ((target_total.as_nanos() as f64 / probe).ceil() as usize).clamp(3, 10_000);
    bench(iters.min(3), iters, f)
}

/// Simple fixed-width table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<width$}  ", c, width = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", widths.iter().map(|w| "-".repeat(*w + 2)).collect::<String>());
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.median_ns, 3.0);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 100.0);
        assert!((s.mean_ns - 22.0).abs() < 1e-9);
    }

    #[test]
    fn stats_even_median() {
        let s = Stats::from_samples(vec![4.0, 1.0, 3.0, 2.0]);
        assert_eq!(s.median_ns, 2.5);
    }

    #[test]
    fn bench_counts_iters() {
        let mut count = 0;
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ns(512.0), "512 ns");
        assert_eq!(fmt_ns(2_500.0), "2.50 µs");
        assert_eq!(fmt_ns(3_000_000.0), "3.00 ms");
        assert_eq!(fmt_ns(1.5e9), "1.500 s");
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024), "5.0 MiB");
    }

    #[test]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(&["only-one".into()])
        }));
        assert!(r.is_err());
    }
}
