//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320).
//!
//! The vendor set has no checksum crate, so payload integrity for the
//! encoded-batch dump format and the `state_io` checkpoint format is
//! computed here. Table-driven, one byte per step — fast enough for the
//! sizes we checksum (batch payloads and checkpoint blobs), and the
//! streaming [`Crc32`] form lets callers fold multi-part buffers without
//! concatenating them first.

const POLY: u32 = 0xEDB8_8320;

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// Streaming CRC-32: `update` in any chunking, then `finish`.
#[derive(Clone, Copy, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    pub fn finish(self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_check_value() {
        // the canonical CRC-32 check vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn empty_input() {
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn chunking_is_invisible() {
        let data: Vec<u8> = (0u16..1024).map(|i| (i % 251) as u8).collect();
        let whole = crc32(&data);
        for split in [1usize, 7, 100, 1023] {
            let mut c = Crc32::new();
            c.update(&data[..split]);
            c.update(&data[split..]);
            assert_eq!(c.finish(), whole, "split at {split}");
        }
    }

    #[test]
    fn single_bit_flips_are_detected() {
        let data: Vec<u8> = (0u16..256).map(|i| i as u8).collect();
        let base = crc32(&data);
        for at in [0usize, 17, 128, 255] {
            for bit in 0..8 {
                let mut bad = data.clone();
                bad[at] ^= 1 << bit;
                assert_ne!(crc32(&bad), base, "flip at {at}:{bit} undetected");
            }
        }
    }
}
