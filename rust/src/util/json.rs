//! Minimal JSON parser + writer.
//!
//! The offline vendor set has no `serde_json`, so the artifact manifest
//! (`artifacts/manifest.json`, emitted by `python/compile/aot.py`) is parsed
//! with this module. It supports the full JSON grammar we emit: objects,
//! arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document; trailing whitespace allowed,
    /// trailing garbage is an error.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(p.err("trailing garbage after document"));
        }
        Ok(v)
    }

    // -- typed accessors -----------------------------------------------------

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj["key"]` lookup that tolerates non-objects by returning None.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Serialize back to compact JSON text.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let rest = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Builder helpers so rust code can emit manifests/reports symmetrically.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(vals: Vec<Json>) -> Json {
    Json::Arr(vals)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn n(v: f64) -> Json {
    Json::Num(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x");
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parses_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"q\" A");
    }

    #[test]
    fn parses_unicode_content() {
        let j = Json::parse("\"héllo ∆\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo ∆");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'a': 1}").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x",true,null],"nested":{"k":"v"},"num":-7}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn roundtrip_escapes() {
        let j = Json::Str("line\nquote\"back\\".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn roundtrip_control_and_quote_chars() {
        // Trace labels carry user-authored fault-spec text and arbitrary
        // degradation-rung strings — every control char (incl. \u{8}/\u{c},
        // which parse back via \b/\f), quotes, backslashes and DEL must
        // survive write → parse unchanged.
        let mut all_controls = String::new();
        for c in 0u32..0x20 {
            all_controls.push(char::from_u32(c).unwrap());
        }
        for text in [
            all_controls.as_str(),
            "link-slow:0.1,x4",
            "seed=7;worker-panic@4;corrupt@2;budget-shrink@6=1MiB",
            "quote\" backslash\\ slash/ del\u{7f}",
            "\u{8}\u{c}\n\r\t",
            "héllo ∆ — µs",
        ] {
            let j = Json::Str(text.into());
            let out = j.to_string();
            assert_eq!(Json::parse(&out).unwrap(), j, "round-trip broke for {out}");
        }
        // spot-check the wire form: controls below 0x20 are never raw
        let wire = Json::Str(all_controls).to_string();
        assert!(wire.bytes().all(|b| b >= 0x20), "raw control byte in {wire:?}");
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::Num(5.0).as_usize(), Some(5));
        assert_eq!(Json::Num(5.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse("[ ]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn builders() {
        let j = obj(vec![("k", arr(vec![n(1.0), s("two")]))]);
        assert_eq!(j.to_string(), r#"{"k":[1,"two"]}"#);
    }
}
