//! Leveled stderr logger with wall-clock-since-start stamps.
//!
//! Controlled by `OPTORCH_LOG` (error|warn|info|debug|trace, default info).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != u8::MAX {
        return v;
    }
    let lvl = std::env::var("OPTORCH_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Info) as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Override the level programmatically (tests, CLI flag).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed();
    eprintln!("[{:>9.3}s {} {}] {}", t.as_secs_f64(), l.tag(), module, msg);
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("TRACE"), Some(Level::Trace));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn set_level_gates() {
        set_level(Level::Error);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
    }
}
