//! Substrate utilities: RNG, JSON, property testing, bench harness, logging.

pub mod bench;
pub mod crc;
pub mod json;
pub mod log;
pub mod propcheck;
pub mod rng;
