//! Mini property-testing framework (proptest substitute — see DESIGN.md §5).
//!
//! Runs a property over many seeded random cases; on failure it reports the
//! reproducing seed and, for `Shrink` inputs, greedily shrinks to a smaller
//! counterexample. Used by the coordinator/data/memory test suites.

use crate::util::rng::Rng;

/// Number of random cases per property (override with OPTORCH_PROPCHECK_CASES).
pub fn default_cases() -> usize {
    std::env::var("OPTORCH_PROPCHECK_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(128)
}

/// Result of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` random inputs produced by `gen`.
/// Panics with the seed and shrunk input description on failure.
pub fn check<T, G, P>(name: &str, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> PropResult,
{
    check_with(name, default_cases(), 0xC0FFEE, gen, prop)
}

/// Like [`check`] with explicit case count and base seed.
pub fn check_with<T, G, P>(name: &str, cases: usize, base_seed: u64, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> PropResult,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}):\n  input: {input:?}\n  error: {msg}"
            );
        }
    }
}

/// Inputs that know how to propose smaller variants of themselves.
pub trait Shrink: Sized {
    /// Candidate strictly-smaller inputs, most aggressive first.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![self / 2, self - 1]
        }
    }
}

impl<T: Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        if self.is_empty() {
            return vec![];
        }
        let mut out = vec![self[..self.len() / 2].to_vec()];
        if self.len() > 1 {
            out.push(self[..self.len() - 1].to_vec());
            out.push(self[1..].to_vec());
        }
        out
    }
}

/// [`check`] plus greedy shrinking on failure for `Shrink` inputs.
pub fn check_shrink<T, G, P>(name: &str, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone + Shrink,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> PropResult,
{
    let cases = default_cases();
    for case in 0..cases {
        let seed = 0xC0FFEEu64.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // Greedy shrink: repeatedly take the first failing candidate.
            let mut best = input.clone();
            let mut msg = first_msg;
            'outer: loop {
                for cand in best.shrink() {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}):\n  shrunk input: {best:?}\n  error: {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("reverse twice is identity", |r| {
            let n = r.gen_range(32);
            (0..n).map(|_| r.next_u32()).collect::<Vec<_>>()
        }, |v| {
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            if w == *v { Ok(()) } else { Err("mismatch".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", |r| r.gen_range(10), |_| Err("nope".into()));
    }

    #[test]
    #[should_panic(expected = "shrunk input: []")]
    fn shrinks_vec_to_minimal() {
        // Property "vec is non-empty implies first element < 1000" fails for
        // everything; minimal counterexample is the empty vec only if the
        // property also fails there — make it fail everywhere so shrinking
        // bottoms out at [].
        check_shrink(
            "fails everywhere",
            |r| {
                let n = r.gen_range(16) + 1;
                (0..n as u32).collect::<Vec<u32>>()
            },
            |_v: &Vec<u32>| Err("always".into()),
        );
    }

    #[test]
    fn usize_shrink_descends() {
        let mut v = 100usize;
        let mut steps = 0;
        while let Some(&next) = v.shrink().first() {
            assert!(next < v);
            v = next;
            steps += 1;
            if v == 0 {
                break;
            }
        }
        assert!(steps <= 100);
        assert_eq!(v, 0);
    }
}
