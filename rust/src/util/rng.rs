//! Deterministic, splittable PRNG (xoshiro256** seeded via splitmix64).
//!
//! The vendor set has no `rand` crate, so the whole repo uses this
//! implementation. Everything downstream (synthetic data, samplers,
//! augmentations, property tests) is seeded through it, which makes every
//! experiment in EXPERIMENTS.md exactly reproducible.

/// xoshiro256** generator. Small, fast, passes BigCrush; plenty for data
/// generation and shuffling (not cryptographic).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a labeled subsystem. Streams derived
    /// with different labels from the same parent are statistically
    /// independent; the same (parent, label) pair always yields the same
    /// stream.
    pub fn split(&self, label: u64) -> Rng {
        let mut sm = self
            .s[0]
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(label.wrapping_mul(0xD2B74407B1CE6E93));
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` (Lemire's method, bias-free for our use).
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_range bound must be > 0");
        (self.next_u64() % bound as u64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn gen_range_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + self.gen_range(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let mut u1 = self.f64();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index according to non-negative weights (need not sum to 1).
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must have positive sum");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_streams_are_reproducible_and_distinct() {
        let root = Rng::new(7);
        let mut s1 = root.split(1);
        let mut s1b = root.split(1);
        let mut s2 = root.split(2);
        assert_eq!(s1.next_u64(), s1b.next_u64());
        let mut s1 = root.split(1);
        let same = (0..64).filter(|_| s1.next_u64() == s2.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..10_000 {
            assert!(r.gen_range(7) < 7);
        }
        for _ in 0..10_000 {
            let v = r.gen_range_in(3, 9);
            assert!((3..9).contains(&v));
        }
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut r = Rng::new(6);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.gen_range(10)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn shuffle_handles_degenerate_sizes() {
        let mut r = Rng::new(9);
        let mut empty: [u8; 0] = [];
        r.shuffle(&mut empty);
        let mut one = [42];
        r.shuffle(&mut one);
        assert_eq!(one, [42]);
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(10);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::new(11);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    #[should_panic]
    fn weighted_index_rejects_zero_sum() {
        let mut r = Rng::new(12);
        r.weighted_index(&[0.0, 0.0]);
    }
}
