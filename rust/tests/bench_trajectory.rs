//! Bench-trajectory tracker: diff freshly emitted `BENCH_*.json` figures
//! against the committed snapshots in `BENCH_baseline/`, failing on a
//! >10% regression of any tracked lower-is-better figure.
//!
//! The flow in CI's bench-smoke job: the `OPTORCH_BENCH_CHECK=1` bench
//! runs write `BENCH_*.json` into the crate root, then this test runs
//! and compares them. Under a plain `cargo test` (no bench artifacts on
//! disk) each comparison **skips** rather than fails, so tier-1 stays
//! hermetic.
//!
//! Baselines are committed JSON (`{"figures": {name: value}}`). The
//! initial seeds sit at the benches' own hard-gate levels; once CI has
//! measured numbers, tightening a baseline turns the 10% band into a
//! real ratchet. Keep noise headroom when you tighten — the band is
//! multiplicative, so a 0.1%-overhead baseline would gate at 0.11%.

use optorch::util::json::Json;
use std::path::PathBuf;

/// Crate root: tests run with CWD = the crate, same place the benches
/// drop their `BENCH_*.json`.
fn crate_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// Fresh bench output, if the bench has run. Benches write to the CWD
/// they were invoked from, so probe both the invocation CWD and the
/// crate root.
fn fresh(name: &str) -> Option<Json> {
    let candidates = [PathBuf::from(name), crate_root().join(name)];
    let text = candidates.iter().find_map(|p| std::fs::read_to_string(p).ok())?;
    Some(Json::parse(&text).unwrap_or_else(|e| panic!("{name}: fresh output is not JSON: {e:?}")))
}

fn baseline(name: &str) -> (PathBuf, Json) {
    let path = crate_root().join("BENCH_baseline").join(name);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing committed baseline {}: {e}", path.display()));
    let json = Json::parse(&text)
        .unwrap_or_else(|e| panic!("{}: baseline is not JSON: {e:?}", path.display()));
    (path, json)
}

fn figure(json: &Json, key: &str, what: &str) -> f64 {
    json.get(key)
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("{what}: missing numeric figure '{key}'"))
}

/// Allowed regression band: 10% over the committed snapshot.
const BAND: f64 = 1.10;

/// Compare every tracked figure of one bench; returns the failures.
fn diff(name: &str, tracked: &[&str]) -> Vec<String> {
    let (base_path, base) = baseline(name);
    let base_figures = base
        .get("figures")
        .unwrap_or_else(|| panic!("{}: baseline lacks a 'figures' object", base_path.display()));
    // Tracked keys must exist in the baseline even when the fresh run is
    // absent — a typo'd table should fail loudly, not skip silently.
    for key in tracked {
        figure(base_figures, key, &format!("baseline {name}"));
    }
    let Some(fresh) = fresh(name) else {
        eprintln!("SKIP {name}: no fresh bench output (run the bench first)");
        return Vec::new();
    };
    let mut failures = Vec::new();
    for key in tracked {
        let was = figure(base_figures, key, &format!("baseline {name}"));
        let now = figure(&fresh, key, &format!("fresh {name}"));
        let allowed = was * BAND;
        if now > allowed {
            failures.push(format!(
                "{name}: {key} regressed {now:.3} > {allowed:.3} (baseline {was:.3} +10%)"
            ));
        } else {
            eprintln!("OK {name}: {key} {now:.3} within {allowed:.3}");
        }
    }
    failures
}

#[test]
fn tracked_bench_figures_stay_inside_the_band() {
    // Lower-is-better figures only; ratios and per-op costs are the
    // machine-stable subset worth ratcheting.
    let table: &[(&str, &[&str])] = &[
        (
            "BENCH_trace.json",
            &["enabled_overhead_pct", "disabled_overhead_pct", "ns_per_span_enabled"],
        ),
        ("BENCH_obs.json", &["overhead_pct", "ns_per_sample", "us_per_scrape"]),
        ("BENCH_serve.json", &["p99_ms_nominal", "us_per_cached_plan"]),
    ];
    let mut failures = Vec::new();
    for (name, tracked) in table {
        failures.extend(diff(name, tracked));
    }
    assert!(failures.is_empty(), "bench trajectory regressions:\n{}", failures.join("\n"));
}

#[test]
fn every_baseline_snapshot_is_wellformed() {
    let dir = crate_root().join("BENCH_baseline");
    let entries: Vec<_> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("missing {}: {e}", dir.display()))
        .filter_map(Result::ok)
        .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
        .collect();
    assert!(!entries.is_empty(), "BENCH_baseline/ holds no snapshots");
    for entry in entries {
        let path = entry.path();
        let text = std::fs::read_to_string(&path).expect("readable snapshot");
        let json = Json::parse(&text)
            .unwrap_or_else(|e| panic!("{}: not JSON: {e:?}", path.display()));
        let figures = json
            .get("figures")
            .and_then(|f| f.as_obj())
            .unwrap_or_else(|| panic!("{}: lacks a 'figures' object", path.display()));
        assert!(!figures.is_empty(), "{}: empty figures", path.display());
        for (key, value) in figures {
            let v = value
                .as_f64()
                .unwrap_or_else(|| panic!("{}: figure '{key}' not numeric", path.display()));
            assert!(v.is_finite() && v > 0.0, "{}: figure '{key}' = {v}", path.display());
        }
    }
}

/// The band math itself (pure, no filesystem).
#[test]
fn regression_band_is_ten_percent() {
    assert!(5.49 <= 5.0 * BAND);
    assert!(5.51 > 5.0 * BAND);
}
