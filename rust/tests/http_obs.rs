//! End-to-end smoke test of the live-metrics endpoint: a real
//! `ObsServer` on an OS-assigned port, scraped over TCP with a
//! hand-rolled HTTP/1.1 client. The stub runtime bails before the
//! trainer can own the server, so these tests drive the `MetricsHub`
//! the same way the trainer does — including feeding it a *real*
//! degradation episode from `PlanRequest::run_degraded` to flip
//! `/readyz`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use optorch::config::Pipeline;
use optorch::fault::DegradeTrigger;
use optorch::memory::pipeline::PlanRequest;
use optorch::obs::{MemTimeline, MetricsHub, ObsServer, StepSample};
use optorch::serve::ServeConfig;

/// Minimal scrape client: one GET, `Connection: close`, returns
/// (status, headers, body).
fn get(addr: SocketAddr, path: &str) -> (u16, String, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    write!(stream, "GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n")
        .expect("request");
    let mut raw = String::new();
    stream.read_to_string(&mut raw).expect("response");
    let (head, body) = raw.split_once("\r\n\r\n").expect("header/body split");
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line in {head:?}"));
    (status, head.to_string(), body.to_string())
}

/// Value of a sample line `name value` in a Prometheus exposition.
fn series_value(exposition: &str, name: &str) -> f64 {
    exposition
        .lines()
        .find_map(|l| l.strip_prefix(name).and_then(|rest| rest.trim().parse::<f64>().ok()))
        .unwrap_or_else(|| panic!("series '{name}' not found in exposition"))
}

/// Validate the text-exposition grammar: every line is a `# HELP`,
/// `# TYPE ... gauge|counter` or a `name[{k="v",...}] value` sample with
/// a legal metric name, well-formed labels and a float value; every
/// sample is preceded by a TYPE for its base name.
fn assert_parses_as_exposition(text: &str) {
    let mut typed: Vec<String> = Vec::new();
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        if let Some(rest) = line.strip_prefix("# ") {
            let mut parts = rest.split_whitespace();
            let keyword = parts.next().unwrap_or("");
            let name = parts.next().unwrap_or("");
            assert!(
                keyword == "HELP" || keyword == "TYPE",
                "unknown comment keyword in '{line}'"
            );
            assert!(!name.is_empty(), "comment without metric name: '{line}'");
            if keyword == "TYPE" {
                let kind = parts.next().unwrap_or("");
                assert!(kind == "gauge" || kind == "counter", "bad TYPE in '{line}'");
                typed.push(name.to_string());
            }
            continue;
        }
        // Label values never contain spaces (the hub sanitizes them), so
        // the first space always separates the series from its value.
        let (series, value) =
            line.split_once(' ').unwrap_or_else(|| panic!("bad sample '{line}'"));
        let name = match series.split_once('{') {
            Some((base, labels)) => {
                let labels = labels
                    .strip_suffix('}')
                    .unwrap_or_else(|| panic!("unclosed label set in '{line}'"));
                for pair in labels.split(',') {
                    let (k, v) =
                        pair.split_once('=').unwrap_or_else(|| panic!("bad label '{pair}'"));
                    assert!(
                        !k.is_empty()
                            && k.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'),
                        "illegal label name '{k}' in '{line}'"
                    );
                    assert!(
                        v.len() >= 2 && v.starts_with('"') && v.ends_with('"'),
                        "unquoted label value '{v}' in '{line}'"
                    );
                }
                base
            }
            None => series,
        };
        assert!(
            name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
            "illegal metric name '{name}'"
        );
        assert!(value.trim().parse::<f64>().is_ok(), "non-float value in '{line}'");
        assert!(typed.contains(&name.to_string()), "sample '{name}' missing its # TYPE");
    }
}

fn serve(hub: &Arc<MetricsHub>) -> ObsServer {
    ObsServer::bind("127.0.0.1:0", hub.clone()).expect("bind ephemeral port")
}

#[test]
fn scrape_reflects_a_simulated_run() {
    // Plan exactly like `train` does and replay 5 steps into the hub.
    let outcome = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
        .pipeline(Pipeline::parse("ed+sc").expect("pipeline"))
        .batch(8)
        .run()
        .expect("plan");
    let timeline = MemTimeline::from_outcome(&outcome).expect("timeline");
    let hub = Arc::new(MetricsHub::new());
    for step in 0..5u64 {
        hub.record_step(StepSample {
            step,
            slab_high_water_bytes: timeline.slab_high_water_bytes(),
            host_resident_bytes: 0,
            scratch_used_bytes: 64,
            scratch_high_water_bytes: 128,
            link_retry_backlog: 0,
            loader_queue_depth: 2,
            degrade_rung: 0,
            step_secs: 0.004,
        });
    }
    let server = serve(&hub);
    let addr = server.local_addr();

    let (status, head, body) = get(addr, "/metrics");
    assert_eq!(status, 200);
    assert!(
        head.contains("text/plain; version=0.0.4"),
        "missing exposition content type: {head}"
    );
    assert_parses_as_exposition(&body);
    for name in [
        "optorch_up",
        "optorch_ready",
        "optorch_arena_slab_high_water_bytes",
        "optorch_arena_scratch_used_bytes",
        "optorch_arena_scratch_high_water_bytes",
        "optorch_host_resident_bytes",
        "optorch_link_retry_backlog",
        "optorch_loader_queue_depth",
        "optorch_degrade_rung",
        "optorch_step_time_ewma_seconds",
        "optorch_steps_total",
        "optorch_samples_dropped_total",
    ] {
        assert!(body.contains(&format!("\n{name} ")), "series '{name}' missing:\n{body}");
    }
    assert_eq!(series_value(&body, "optorch_steps_total") as u64, 5);
    assert_eq!(
        series_value(&body, "optorch_arena_slab_high_water_bytes") as u64,
        timeline.slab_high_water_bytes(),
        "gauge must mirror the plan-replayed slab high-water mark"
    );
    assert_eq!(series_value(&body, "optorch_loader_queue_depth") as u64, 2);
    assert!(series_value(&body, "optorch_step_time_ewma_seconds") > 0.0);

    // liveness + readiness agree with a healthy run
    assert_eq!(get(addr, "/healthz").0, 200);
    let (ready_status, _, ready_body) = get(addr, "/readyz");
    assert_eq!(ready_status, 200);
    assert_eq!(ready_body, "ready\n");
    assert_eq!(series_value(&body, "optorch_ready") as u64, 1);
}

#[test]
fn readyz_flips_503_after_a_real_budget_shrink_episode() {
    let hub = Arc::new(MetricsHub::new());
    let server = serve(&hub);
    let addr = server.local_addr();
    assert_eq!(get(addr, "/readyz").0, 200, "healthy before the fault");

    // Inject the fault the way the trainer's replan path does: a budget
    // shrink so severe the degradation ladder must walk to a fallback,
    // then feed the episode's rung count to the hub.
    let (_outcome, report) = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
        .pipeline(Pipeline::parse("ed+sc").expect("pipeline"))
        .batch(8)
        .memory_budget(1)
        .run_degraded(DegradeTrigger::BudgetShrink { from: None, to: 1 })
        .expect("the ladder tolerates an infeasible budget");
    assert!(!report.actions.is_empty(), "a 1-byte budget must cost at least one rung");
    hub.note_degrade_event(report.actions.len() as u64);

    let (status, _, body) = get(addr, "/readyz");
    assert_eq!(status, 503, "degraded run must fail readiness");
    assert_eq!(body, "degraded\n");
    assert_eq!(get(addr, "/healthz").0, 200, "liveness is unaffected");

    let (_, _, metrics) = get(addr, "/metrics");
    assert_eq!(series_value(&metrics, "optorch_ready") as u64, 0);
    assert_eq!(series_value(&metrics, "optorch_degrade_events_total") as u64, 1);
    assert_eq!(
        series_value(&metrics, "optorch_degrade_rungs_total") as u64,
        report.actions.len() as u64,
        "/metrics and the DegradationReport must agree on rungs"
    );
}

#[test]
fn readyz_latches_on_loader_watchdog() {
    let hub = Arc::new(MetricsHub::new());
    let server = serve(&hub);
    let addr = server.local_addr();
    assert_eq!(get(addr, "/readyz").0, 200);
    hub.set_watchdog_fired();
    assert_eq!(get(addr, "/readyz").0, 503);
    // the latch never clears — a stalled loader is not a transient
    assert_eq!(get(addr, "/readyz").0, 503);
}

#[test]
fn serve_series_and_phase_quantiles_scrape_live() {
    let hub = Arc::new(MetricsHub::new());
    let server = serve(&hub);
    let addr = server.local_addr();
    let cfg = ServeConfig {
        requests: 64,
        clients: 4,
        think_ms: 10.0,
        deadline_ms: 200.0,
        max_batch: 8,
        ..ServeConfig::default_for("tiny_cnn")
    };
    let rep = optorch::serve::run(&cfg, &hub).expect("serve run");
    assert_eq!(rep.completed, 64, "nominal load completes everything");

    let (status, _, body) = get(addr, "/metrics");
    assert_eq!(status, 200);
    assert_parses_as_exposition(&body);
    assert!(body.contains("\noptorch_serve_queue_depth "), "queue gauge missing:\n{body}");
    assert_eq!(series_value(&body, "optorch_serve_admitted_total") as u64, 64);
    assert_eq!(series_value(&body, "optorch_serve_shed_total") as u64, 0);
    assert!(series_value(&body, "optorch_serve_batches_total") > 0.0);
    assert!(
        body.contains("optorch_serve_batch_size{quantile=\"0.5\"}"),
        "labeled batch-size quantiles missing:\n{body}"
    );
    for phase in ["serve-queue-wait", "serve-service", "serve-e2e"] {
        assert!(
            body.contains(&format!("optorch_phase_seconds{{phase=\"{phase}\",quantile=\"0.99\"}}")),
            "phase gauge for '{phase}' missing:\n{body}"
        );
    }
    assert_eq!(get(addr, "/readyz").0, 200, "zero sheds keep readiness green");
}

#[test]
fn readyz_flips_503_while_serve_shed_rate_nonzero() {
    let hub = Arc::new(MetricsHub::new());
    let server = serve(&hub);
    let addr = server.local_addr();
    assert_eq!(get(addr, "/readyz").0, 200, "ready before any traffic");

    // A budget nothing fits: every request sheds budget-exceeded, so the
    // windowed shed rate is pinned above zero.
    let cfg = ServeConfig {
        budget: Some(1024),
        requests: 16,
        clients: 2,
        shed_window: 32,
        ..ServeConfig::default_for("tiny_cnn")
    };
    let rep = optorch::serve::run(&cfg, &hub).expect("serve run");
    assert_eq!(rep.shed_budget, 16, "nothing fits a 1 KiB device");

    let (status, _, body) = get(addr, "/readyz");
    assert_eq!(status, 503, "nonzero shed rate over the window fails readiness");
    assert_eq!(body, "degraded\n");
    assert_eq!(get(addr, "/healthz").0, 200, "liveness is unaffected");
    let (_, _, metrics) = get(addr, "/metrics");
    assert_eq!(series_value(&metrics, "optorch_serve_shed_total") as u64, 16);
    assert!(series_value(&metrics, "optorch_serve_shed_rate_window") > 0.0);
}

#[test]
fn unknown_paths_and_queries_route_sanely() {
    let hub = Arc::new(MetricsHub::new());
    let server = serve(&hub);
    let addr = server.local_addr();
    assert_eq!(get(addr, "/nope").0, 404);
    let (status, _, body) = get(addr, "/healthz?verbose=1");
    assert_eq!(status, 200, "query strings are stripped");
    assert_eq!(body, "ok\n");
}
