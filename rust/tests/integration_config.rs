//! Config-file + CLI-override integration, and failure-injection tests on
//! the data pipeline (corrupted dumps, panicking producers, bad configs).

use optorch::cli::Cli;
use optorch::config::TrainConfig;
use optorch::data::loader::dump;
use std::collections::BTreeMap;

#[test]
fn shipped_config_files_parse() {
    for name in ["configs/quickstart.toml", "configs/fig9_cell.toml"] {
        let text = std::fs::read_to_string(name).unwrap();
        let cfg = TrainConfig::from_sources(Some(&text), &BTreeMap::new())
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        cfg.validate().unwrap();
    }
}

#[test]
fn cli_overrides_beat_config_file() {
    let text = std::fs::read_to_string("configs/quickstart.toml").unwrap();
    let mut ov = BTreeMap::new();
    ov.insert("epochs".to_string(), "1".to_string());
    ov.insert("pipeline".to_string(), "mp".to_string());
    let cfg = TrainConfig::from_sources(Some(&text), &ov).unwrap();
    assert_eq!(cfg.epochs, 1);
    assert_eq!(cfg.pipeline.name(), "mp");
    assert_eq!(cfg.model, "tiny_cnn"); // from file
}

#[test]
fn cli_parse_mirrors_train_config_keys() {
    // every --key the launcher forwards must be accepted by from_sources
    let cli = Cli::parse(
        "train --model tiny_cnn --pipeline ed+sc --epochs 2 --batch_size 16 \
         --train_size 320 --test_size 64 --seed 9 --prefetch_depth 2 \
         --num_workers 3 --augment hflip --eval_every 1 \
         --max_batches_per_epoch 3 --dataset synth10"
            .split_whitespace()
            .map(String::from),
    )
    .unwrap();
    let cfg = TrainConfig::from_sources(None, &cli.opts).unwrap();
    assert_eq!(cfg.model, "tiny_cnn");
    assert_eq!(cfg.seed, 9);
    assert_eq!(cfg.max_batches_per_epoch, 3);
    assert_eq!(cfg.num_workers, Some(3));
}

#[test]
fn corrupted_dump_bytes_never_panic() {
    // fuzz the dump parser with truncations and bit flips of a valid blob
    use optorch::data::encode::{encode_batch, EncodeSpec, Encoding, WordType};
    use optorch::data::image::ImageBatch;
    let mut batch = ImageBatch::zeros(4, 6, 6, 3, 10);
    for (i, v) in batch.data.iter_mut().enumerate() {
        *v = (i % 251) as u8;
    }
    let blob = dump::to_bytes(
        &encode_batch(&batch, EncodeSpec::new(Encoding::Base256, WordType::U64)).unwrap(),
    );
    // truncation at every prefix boundary
    for cut in (0..blob.len()).step_by(7) {
        let _ = dump::from_bytes(&blob[..cut]); // must return Err, not panic
    }
    // bit flips across the header region
    let mut rng = optorch::util::rng::Rng::new(1);
    for _ in 0..200 {
        let mut bad = blob.clone();
        let at = rng.gen_range(bad.len().min(64));
        bad[at] ^= 1 << rng.gen_range(8);
        let _ = dump::from_bytes(&bad); // Err or equivalent batch — never panic
    }
}

#[test]
fn loader_drop_under_backpressure_terminates() {
    // producers blocked on a full queue + consumer drops: must not deadlock,
    // for the legacy single producer and for the worker pool alike
    use optorch::data::augment::AugPolicy;
    use optorch::data::dataset::Dataset;
    use optorch::data::loader::{EdLoader, LoaderMode};
    use optorch::data::sampler::SbsSampler;
    use optorch::data::synth::{Split, SynthCifar};
    use std::sync::Arc;
    for num_workers in [0, 1, 4] {
        for _ in 0..3 {
            let d: Arc<dyn Dataset> = Arc::new(SynthCifar::cifar10(Split::Train, 400, 3));
            let sampler = SbsSampler::uniform(d.as_ref(), 16, AugPolicy::none(), 1).unwrap();
            let mut loader = EdLoader::new(
                d,
                sampler,
                None,
                50,
                LoaderMode::Parallel { prefetch_depth: 1, num_workers },
            );
            let _ = loader.next();
            drop(loader);
        }
    }
}

#[test]
fn bad_config_values_error_cleanly() {
    for (k, v) in [
        ("pipeline", "hyperdrive"),
        ("dataset", "imagenet"),
        ("batch_size", "zero"),
        ("augment", "sharpen5"),
    ] {
        let mut ov = BTreeMap::new();
        ov.insert(k.to_string(), v.to_string());
        assert!(
            TrainConfig::from_sources(None, &ov).is_err(),
            "{k}={v} should fail"
        );
    }
}
