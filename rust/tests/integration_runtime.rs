//! Integration: PJRT runtime against the real AOT artifacts.
//!
//! Requires `make artifacts`; each test skips (with a loud note) when the
//! manifest is missing so `cargo test` stays runnable in a fresh checkout.

use optorch::data::loader::BatchPayload;
use optorch::runtime::{BatchKind, Runtime};
use std::path::Path;

fn runtime() -> Option<Runtime> {
    if !Path::new("artifacts/manifest.json").is_file() {
        eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
        return None;
    }
    Some(Runtime::new(Path::new("artifacts")).expect("runtime"))
}

fn raw_batch(n: usize, seed: u64) -> BatchPayload {
    let mut rng = optorch::util::rng::Rng::new(seed);
    let data: Vec<f32> = (0..n * 32 * 32 * 3).map(|_| rng.f32()).collect();
    let mut labels = vec![0.0f32; n * 10];
    for i in 0..n {
        labels[i * 10 + rng.gen_range(10)] = 1.0;
    }
    BatchPayload::Raw { data, labels, n }
}

#[test]
fn manifest_lists_expected_grid() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest();
    assert!(m.entries.len() >= 20, "only {} entries", m.entries.len());
    for model in ["tiny_cnn", "resnet_mini18", "effnet_lite", "inception_lite"] {
        for pipe in ["baseline", "ed", "mp", "sc", "ed_mp_sc"] {
            assert!(m.find(model, pipe).is_some(), "missing {model}/{pipe}");
        }
    }
    // every referenced HLO file exists
    for e in &m.entries {
        for f in [&e.train_hlo, &e.eval_hlo, &e.init_hlo] {
            assert!(m.hlo_path(f).is_file(), "missing {f}");
        }
    }
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    let Some(mut rt) = runtime() else { return };
    let model = rt.load("tiny_cnn", "baseline").unwrap();
    let a = model.init_state(7).unwrap();
    let b = model.init_state(7).unwrap();
    let c = model.init_state(8).unwrap();
    assert_eq!(a.len(), model.entry.state.len());
    let bytes = |s: &optorch::runtime::TrainState| {
        s.tensors
            .iter()
            .map(|t| t.to_vec::<f32>().unwrap_or_default())
            .collect::<Vec<_>>()
    };
    assert_eq!(bytes(&a), bytes(&b));
    assert_ne!(bytes(&a), bytes(&c));
}

#[test]
fn train_step_reduces_loss_on_fixed_batch() {
    let Some(mut rt) = runtime() else { return };
    let model = rt.load("tiny_cnn", "baseline").unwrap();
    let mut state = model.init_state(42).unwrap();
    let batch = raw_batch(16, 1);
    let first = model.train_step(&mut state, &batch).unwrap();
    let mut last = first;
    for _ in 0..10 {
        last = model.train_step(&mut state, &batch).unwrap();
    }
    assert!(
        last.loss < first.loss * 0.8,
        "loss {} -> {}",
        first.loss,
        last.loss
    );
    assert!(last.correct <= 16);
}

#[test]
fn eval_step_does_not_mutate_state() {
    let Some(mut rt) = runtime() else { return };
    let model = rt.load("tiny_cnn", "baseline").unwrap();
    let mut state = model.init_state(3).unwrap();
    let batch = raw_batch(16, 2);
    let before: Vec<Vec<f32>> = state.tensors.iter().map(|t| t.to_vec().unwrap()).collect();
    let e1 = model.eval_step(&state, &batch).unwrap();
    let e2 = model.eval_step(&state, &batch).unwrap();
    let after: Vec<Vec<f32>> = state.tensors.iter().map(|t| t.to_vec().unwrap()).collect();
    assert_eq!(before, after);
    assert_eq!(e1.loss, e2.loss);
    assert_eq!(e1.correct, e2.correct);
    // train then expect eval to change
    let _ = model.train_step(&mut state, &batch).unwrap();
    let e3 = model.eval_step(&state, &batch).unwrap();
    assert_ne!(e1.loss, e3.loss);
}

#[test]
fn mp_artifacts_hold_f16_state() {
    let Some(mut rt) = runtime() else { return };
    let model = rt.load("tiny_cnn", "mp").unwrap();
    let state = model.init_state(1).unwrap();
    for (t, spec) in state.tensors.iter().zip(&model.entry.state) {
        assert_eq!(
            t.ty().unwrap(),
            xla::ElementType::F16,
            "state tensor {} not f16",
            spec.name
        );
    }
    // f16 state is half the bytes of the baseline's f32 state
    let model32 = rt.load("tiny_cnn", "baseline").unwrap();
    let state32 = model32.init_state(1).unwrap();
    assert_eq!(state.bytes() * 2, state32.bytes());
}

#[test]
fn ed_artifact_consumes_encoded_groups() {
    let Some(mut rt) = runtime() else { return };
    let model = rt.load("tiny_cnn", "ed").unwrap();
    assert_eq!(model.entry.batch_kind, BatchKind::Encoded);
    assert_eq!(model.entry.groups, 3);
    assert_eq!(model.entry.group_capacity, 6);
    // build a real encoded payload via the data pipeline
    use optorch::data::encode::{encode_batch_grouped, EncodeSpec, Encoding, WordType};
    use optorch::data::image::ImageBatch;
    let mut rng = optorch::util::rng::Rng::new(5);
    let mut img_batch = ImageBatch::zeros(16, 32, 32, 3, 10);
    for v in img_batch.data.iter_mut() {
        *v = (rng.next_u32() & 0xff) as u8;
    }
    for i in 0..16 {
        let c = rng.gen_range(10);
        img_batch.label_mut(i)[c] = 1.0;
    }
    let groups = encode_batch_grouped(
        &img_batch,
        EncodeSpec::new(Encoding::Base256, WordType::F64),
    )
    .unwrap();
    let payload = BatchPayload::Encoded(groups);
    let mut state = model.init_state(9).unwrap();
    let out = model.train_step(&mut state, &payload).unwrap();
    assert!(out.loss.is_finite());
}

#[test]
fn payload_kind_mismatch_is_an_error() {
    let Some(mut rt) = runtime() else { return };
    let model = rt.load("tiny_cnn", "ed").unwrap();
    let mut state = model.init_state(1).unwrap();
    let raw = raw_batch(16, 1);
    let err = model.train_step(&mut state, &raw).unwrap_err();
    assert!(err.to_string().contains("mismatch"), "{err}");
}

#[test]
fn wrong_batch_size_is_an_error() {
    let Some(mut rt) = runtime() else { return };
    let model = rt.load("tiny_cnn", "baseline").unwrap();
    let mut state = model.init_state(1).unwrap();
    let small = raw_batch(8, 1);
    assert!(model.train_step(&mut state, &small).is_err());
}

#[test]
fn unknown_model_is_a_clean_error() {
    let Some(mut rt) = runtime() else { return };
    let err = match rt.load("alexnet", "baseline") {
        Err(e) => e,
        Ok(_) => panic!("expected missing-artifact error"),
    };
    assert!(err.to_string().contains("no artifact"), "{err}");
}

#[test]
fn sc_and_baseline_agree_numerically() {
    // S-C changes the schedule, not the math: identical seed + batch must
    // give near-identical losses for several steps.
    let Some(mut rt) = runtime() else { return };
    let base = rt.load("tiny_cnn", "baseline").unwrap();
    let sc = rt.load("tiny_cnn", "sc").unwrap();
    let mut sb = base.init_state(11).unwrap();
    let mut ss = sc.init_state(11).unwrap();
    let batch = raw_batch(16, 3);
    for step in 0..5 {
        let ob = base.train_step(&mut sb, &batch).unwrap();
        let os = sc.train_step(&mut ss, &batch).unwrap();
        assert!(
            (ob.loss - os.loss).abs() < 1e-4,
            "step {step}: {} vs {}",
            ob.loss,
            os.loss
        );
    }
}

#[test]
fn state_save_load_roundtrip_f32_and_f16() {
    let Some(mut rt) = runtime() else { return };
    let dir = std::env::temp_dir().join(format!("optorch_state_{}", std::process::id()));
    for pipe in ["baseline", "mp"] {
        let model = rt.load("tiny_cnn", pipe).unwrap();
        let mut state = model.init_state(21).unwrap();
        // advance a few steps so the state is non-trivial
        let batch = raw_batch(16, 4);
        for _ in 0..3 {
            model.train_step(&mut state, &batch).unwrap();
        }
        let path = dir.join(format!("{pipe}.state"));
        optorch::runtime::state_io::save(&path, &model.entry, &state).unwrap();
        let restored = optorch::runtime::state_io::load(&path, &model.entry).unwrap();
        // training from the restored state reproduces training from the
        // original state exactly
        let mut a = state;
        let mut b = restored;
        let oa = model.train_step(&mut a, &batch).unwrap();
        let ob = model.train_step(&mut b, &batch).unwrap();
        assert_eq!(oa.loss, ob.loss, "{pipe}");
        assert_eq!(oa.correct, ob.correct, "{pipe}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn state_load_rejects_wrong_pipeline() {
    let Some(mut rt) = runtime() else { return };
    let dir = std::env::temp_dir().join(format!("optorch_state_x_{}", std::process::id()));
    let base = rt.load("tiny_cnn", "baseline").unwrap();
    let state = base.init_state(1).unwrap();
    let path = dir.join("b.state");
    optorch::runtime::state_io::save(&path, &base.entry, &state).unwrap();
    // resnet artifact expects a different tensor list
    let other = rt.load("resnet_mini18", "baseline").unwrap();
    assert!(optorch::runtime::state_io::load(&path, &other.entry).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn lr_input_controls_update_magnitude() {
    let Some(mut rt) = runtime() else { return };
    let model = rt.load("tiny_cnn", "baseline").unwrap();
    let batch = raw_batch(16, 6);
    // lr = 0: parameters must not move (momentum may)
    let mut state = model.init_state(33).unwrap();
    let before: Vec<f32> = state.tensors[2].to_vec().unwrap();
    model.train_step_lr(&mut state, &batch, 0.0).unwrap();
    let n = model.entry.state.len() / 2;
    let after: Vec<f32> = state.tensors[2].to_vec().unwrap();
    assert_eq!(before, after, "lr=0 moved params");
    let _ = n;
    // big lr moves further than small lr from the same start
    let dist = |lr: f32| -> f32 {
        let mut s = model.init_state(33).unwrap();
        let b0: Vec<f32> = s.tensors[2].to_vec().unwrap();
        model.train_step_lr(&mut s, &batch, lr).unwrap();
        let b1: Vec<f32> = s.tensors[2].to_vec().unwrap();
        b0.iter().zip(&b1).map(|(a, b)| (a - b).abs()).sum()
    };
    assert!(dist(0.1) > dist(0.001) * 10.0);
}
