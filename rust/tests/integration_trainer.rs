//! Integration: the full coordinator (dataset → sampler → loader → PJRT →
//! metrics) on short real runs, including the paper's accuracy-equality
//! claim at small scale.

use optorch::config::{Pipeline, TrainConfig};
use optorch::coordinator::{report, Trainer};
use std::path::Path;

fn have_artifacts() -> bool {
    if Path::new("artifacts/manifest.json").is_file() {
        true
    } else {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        false
    }
}

fn quick_cfg(model: &str, pipe: &str) -> TrainConfig {
    let mut cfg = TrainConfig::default_for(model, Pipeline::parse(pipe).unwrap());
    cfg.epochs = 1;
    cfg.train_size = 320;
    cfg.test_size = 96;
    cfg.seed = 1234;
    cfg
}

#[test]
fn trainer_runs_every_pipeline() {
    if !have_artifacts() {
        return;
    }
    for pipe in ["b", "ed", "mp", "sc", "ed+mp", "ed+sc", "mp+sc", "ed+mp+sc"] {
        let cfg = quick_cfg("tiny_cnn", pipe);
        let rep = Trainer::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(rep.history.epochs.len(), 1, "{pipe}");
        let e = &rep.history.epochs[0];
        assert!(e.train_loss.is_finite(), "{pipe}");
        assert_eq!(e.images, 320, "{pipe}");
        assert!(rep.final_eval_accuracy >= 0.0 && rep.final_eval_accuracy <= 1.0);
    }
}

#[test]
fn pipelines_reach_equal_accuracy() {
    // The paper's central claim: optimization pipelines do not change
    // accuracy. Same seed, same data, 2 epochs — require a tight band.
    if !have_artifacts() {
        return;
    }
    let mut accs = Vec::new();
    for pipe in ["b", "ed", "sc", "ed+sc"] {
        let mut cfg = quick_cfg("tiny_cnn", pipe);
        cfg.epochs = 2;
        cfg.train_size = 640;
        let rep = Trainer::from_config(&cfg).unwrap().run().unwrap();
        accs.push((pipe, rep.final_eval_accuracy));
    }
    let max = accs.iter().map(|(_, a)| *a).fold(0.0f64, f64::max);
    let min = accs.iter().map(|(_, a)| *a).fold(1.0f64, f64::min);
    assert!(max - min < 0.15, "accuracy spread too wide: {accs:?}");
}

#[test]
fn same_seed_same_run() {
    if !have_artifacts() {
        return;
    }
    let cfg = quick_cfg("tiny_cnn", "b");
    let a = Trainer::from_config(&cfg).unwrap().run().unwrap();
    let b = Trainer::from_config(&cfg).unwrap().run().unwrap();
    assert_eq!(a.history.epochs[0].train_loss, b.history.epochs[0].train_loss);
    assert_eq!(a.final_eval_accuracy, b.final_eval_accuracy);
}

#[test]
fn different_seeds_differ() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = quick_cfg("tiny_cnn", "b");
    let a = Trainer::from_config(&cfg).unwrap().run().unwrap();
    cfg.seed = 999;
    let b = Trainer::from_config(&cfg).unwrap().run().unwrap();
    assert_ne!(a.history.epochs[0].train_loss, b.history.epochs[0].train_loss);
}

#[test]
fn parallel_ed_loader_feeds_trainer_correctly() {
    if !have_artifacts() {
        return;
    }
    // E-D uses the background producer; loss trajectory must still be sane
    // and producer stats populated.
    let mut cfg = quick_cfg("tiny_cnn", "ed");
    cfg.epochs = 2;
    let rep = Trainer::from_config(&cfg).unwrap().run().unwrap();
    assert!(rep.loader_produce_secs > 0.0);
    let e0 = &rep.history.epochs[0];
    let e1 = &rep.history.epochs[1];
    assert!(e1.train_loss < e0.train_loss, "{} !< {}", e1.train_loss, e0.train_loss);
}

#[test]
fn max_batches_caps_epoch() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = quick_cfg("tiny_cnn", "b");
    cfg.max_batches_per_epoch = 5;
    let rep = Trainer::from_config(&cfg).unwrap().run().unwrap();
    assert_eq!(rep.history.epochs[0].images, 5 * 16);
}

#[test]
fn wrong_batch_size_rejected_at_construction() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = quick_cfg("tiny_cnn", "b");
    cfg.batch_size = 32; // artifacts are compiled for 16
    let err = match Trainer::from_config(&cfg) {
        Err(e) => e,
        Ok(_) => panic!("expected batch-size mismatch error"),
    };
    assert!(err.to_string().contains("batch_size"), "{err}");
}

#[test]
fn report_writers_roundtrip() {
    if !have_artifacts() {
        return;
    }
    let cfg = quick_cfg("tiny_cnn", "b");
    let rep = Trainer::from_config(&cfg).unwrap().run().unwrap();
    let dir = std::env::temp_dir().join(format!("optorch_it_{}", std::process::id()));
    let path = dir.join("h.csv");
    report::write_history_csv(&path, &rep).unwrap();
    let txt = std::fs::read_to_string(&path).unwrap();
    assert!(txt.lines().count() >= 2);
    let md = report::markdown_summary(&rep);
    assert!(md.contains("tiny_cnn"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_binary_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let exe = env!("CARGO_BIN_EXE_optorch");
    let out = std::process::Command::new(exe)
        .args([
            "train",
            "--model",
            "tiny_cnn",
            "--pipeline",
            "ed+sc",
            "--epochs",
            "1",
            "--train_size",
            "160",
            "--test_size",
            "64",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("final eval accuracy"), "{stdout}");

    // memsim + plan + models subcommands
    for args in [
        vec!["memsim", "--model", "resnet18", "--pipeline", "sc"],
        vec!["plan", "--model", "tiny_cnn", "--height", "64"],
        vec!["models"],
        vec!["help"],
    ] {
        let out = std::process::Command::new(exe).args(&args).output().unwrap();
        assert!(out.status.success(), "{args:?}: {}", String::from_utf8_lossy(&out.stderr));
    }

    // unknown command exits non-zero
    let out = std::process::Command::new(exe).arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
}
