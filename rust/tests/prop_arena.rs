//! Property tests for the activation arena: lifetime extraction must
//! replay the exact evaluator peak, packed offsets must never overlap in
//! (time × address), layouts must be deterministic, and greedy packing
//! must stay within 25% of the exact DP peak on random chains.

use optorch::config::Pipeline;
use optorch::memory::arena::{pack, plan_arena, validate, Lifetimes};
use optorch::memory::peak::PeakEvaluator;
use optorch::memory::planner::{plan_checkpoints, PlannerKind};
use optorch::models::{ArchProfile, LayerKind, LayerProfile};
use optorch::util::propcheck::check_with;
use optorch::util::rng::Rng;

/// Random heterogeneous chain respecting the arena invariant
/// `act_elems ≥ out_elems` (every registry profile stores at least its
/// boundary tensor — see the `memory::peak` module docs).
fn rand_chain(rng: &mut Rng, max_layers: usize) -> ArchProfile {
    let n = 1 + rng.gen_range(max_layers);
    let layers = (0..n)
        .map(|i| {
            let h = 1 + rng.gen_range(6);
            let c = 1 + rng.gen_range(48);
            let out = (h * h * c) as u64;
            LayerProfile {
                name: format!("l{i}"),
                kind: LayerKind::Dense,
                out_shape: (h, h, c),
                act_elems: out * (1 + rng.gen_range(4)) as u64,
                params: rng.gen_range(5_000) as u64,
                flops_per_image: (1 + rng.gen_range(900)) as u64 * 1_000,
            }
        })
        .collect();
    ArchProfile {
        name: "rand_chain".into(),
        input: (1 + rng.gen_range(6), 1 + rng.gen_range(6), 3),
        layers,
    }
}

#[test]
fn prop_lifetimes_replay_the_exact_peak() {
    check_with(
        "base + max concurrent live == evaluator peak",
        96,
        0xA2E4A,
        |rng| {
            let arch = rand_chain(rng, 14);
            let n = arch.layers.len();
            // random plan, deliberately including out-of-range indices
            let plan: Vec<usize> = (0..n + 2).filter(|_| rng.gen_range(2) == 1).collect();
            let pipes = ["b", "sc", "mp", "ed+sc", "ed+mp+sc"];
            let pipe = pipes[rng.gen_range(pipes.len())].to_string();
            (arch, plan, pipe, 1 + rng.gen_range(8))
        },
        |(arch, plan, pipe, batch)| {
            let p = Pipeline::parse(pipe).unwrap();
            let mut ev = PeakEvaluator::new(arch, p, *batch);
            let lt = Lifetimes::extract(&ev, plan);
            let got = lt.base_bytes + lt.max_live_bytes();
            let want = ev.peak(plan);
            if got == want {
                Ok(())
            } else {
                Err(format!("lifetimes replay {got} != evaluator peak {want} [{pipe}]"))
            }
        },
    );
}

#[test]
fn prop_packed_layout_sound_and_covers_the_dp_peak() {
    check_with(
        "offsets overlap-free; slab + static ≥ exact DP peak; ratio ≤ 1.25",
        64,
        0x5AB1,
        |rng| (rand_chain(rng, 14), 1 + rng.gen_range(8)),
        |(arch, batch)| {
            let plan = plan_checkpoints(arch, PlannerKind::Optimal, Pipeline::BASELINE, *batch);
            let (lt, layout) = plan_arena(arch, Pipeline::BASELINE, *batch, &plan.checkpoints);
            validate(&lt, &layout)?;
            if layout.peak_bytes != plan.peak_bytes {
                return Err(format!(
                    "layout peak {} != plan peak {}",
                    layout.peak_bytes, plan.peak_bytes
                ));
            }
            if layout.total_bytes() < plan.peak_bytes {
                return Err(format!(
                    "slab + static {} below the exact peak {}",
                    layout.total_bytes(),
                    plan.peak_bytes
                ));
            }
            let ratio = layout.fragmentation_ratio();
            if !(1.0..=1.25).contains(&ratio) {
                return Err(format!("fragmentation ratio {ratio:.3} outside [1.0, 1.25]"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_layout_is_deterministic() {
    check_with(
        "same inputs → byte-identical layout",
        48,
        0xDE7,
        |rng| (rand_chain(rng, 14), 1 + rng.gen_range(8)),
        |(arch, batch)| {
            let plan = plan_checkpoints(arch, PlannerKind::Optimal, Pipeline::BASELINE, *batch);
            let (lt_a, a) = plan_arena(arch, Pipeline::BASELINE, *batch, &plan.checkpoints);
            let (lt_b, b) = plan_arena(arch, Pipeline::BASELINE, *batch, &plan.checkpoints);
            if a.slab_bytes != b.slab_bytes || a.offsets != b.offsets {
                return Err("layout differs across identical runs".into());
            }
            if lt_a.tensors.len() != lt_b.tensors.len() {
                return Err("lifetimes differ across identical runs".into());
            }
            let c = pack(&lt_a);
            if c.slab_bytes != a.slab_bytes || c.offsets != a.offsets {
                return Err("re-packing the same lifetimes diverged".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_heuristic_plans_also_pack_soundly() {
    // The arena must lay out whatever plan the trainer selects, not just
    // the DP optimum: sqrt and uniform plans (and the empty plan) must
    // still produce sound, peak-covering layouts.
    check_with(
        "non-optimal plans pack without overlap and cover their peak",
        48,
        0x9A7C,
        |rng| {
            let arch = rand_chain(rng, 14);
            let kind = match rng.gen_range(3) {
                0 => PlannerKind::Sqrt,
                1 => PlannerKind::Uniform(1 + rng.gen_range(4)),
                _ => PlannerKind::Bottleneck(1 + rng.gen_range(4)),
            };
            (arch, kind, 1 + rng.gen_range(8))
        },
        |(arch, kind, batch)| {
            let plan = plan_checkpoints(arch, *kind, Pipeline::BASELINE, *batch);
            let (lt, layout) = plan_arena(arch, Pipeline::BASELINE, *batch, &plan.checkpoints);
            validate(&lt, &layout)?;
            if layout.peak_bytes != plan.peak_bytes {
                return Err(format!(
                    "layout peak {} != plan peak {} [{kind:?}]",
                    layout.peak_bytes, plan.peak_bytes
                ));
            }
            if layout.total_bytes() < plan.peak_bytes {
                return Err("slab + static below the plan peak".into());
            }
            Ok(())
        },
    );
}
