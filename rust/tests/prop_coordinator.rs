//! Property-based tests (propcheck) on coordinator/data/memory invariants:
//! batching, routing (SBS composition), encode round-trips, loader
//! equivalence, simulator monotonicity, planner validity.

use optorch::config::Pipeline;
use optorch::data::augment::AugPolicy;
use optorch::data::dataset::{Dataset, MemDataset};
use optorch::data::encode::{
    decode_batch, encode_batch, encode_batch_grouped, EncodeSpec, Encoding, WordType,
};
use optorch::data::image::{Image, ImageBatch};
use optorch::data::loader::{dump, BatchPayload, EdLoader, LoaderMode};
use optorch::data::sampler::{ClassSpec, SbsSampler};
use optorch::data::synth::{Split, SynthCifar};
use optorch::memory::planner::{plan_checkpoints, PlannerKind};
use optorch::memory::simulator::simulate;
use optorch::models::arch_by_name;
use optorch::util::propcheck::{check, check_with};
use optorch::util::rng::Rng;
use std::sync::Arc;

fn random_image_batch(rng: &mut Rng, n: usize) -> ImageBatch {
    let h = 1 + rng.gen_range(12);
    let w = 1 + rng.gen_range(12);
    let c = 1 + rng.gen_range(3);
    let mut b = ImageBatch::zeros(n, h, w, c, 10);
    for v in b.data.iter_mut() {
        *v = (rng.next_u32() & 0xff) as u8;
    }
    for i in 0..n {
        let cls = rng.gen_range(10);
        b.label_mut(i)[cls] = 1.0;
    }
    b
}

#[test]
fn prop_encode_roundtrip_any_spec() {
    check("encode/decode roundtrip", |rng| {
        let enc = if rng.bool(0.5) { Encoding::Base256 } else { Encoding::Lossless128 };
        let word = if rng.bool(0.5) { WordType::U64 } else { WordType::F64 };
        let spec = EncodeSpec::new(enc, word);
        let n = 1 + rng.gen_range(spec.capacity());
        (spec, random_image_batch(rng, n))
    }, |(spec, batch)| {
        let encoded = encode_batch(batch, *spec).map_err(|e| e.to_string())?;
        if decode_batch(&encoded) == *batch {
            Ok(())
        } else {
            Err("roundtrip mismatch".into())
        }
    });
}

#[test]
fn prop_grouped_encode_partitions_batch() {
    check("grouped encode partitions", |rng| {
        let n = 1 + rng.gen_range(40);
        random_image_batch(rng, n)
    }, |batch| {
        let spec = EncodeSpec::new(Encoding::Base256, WordType::U64);
        let groups = encode_batch_grouped(batch, spec).map_err(|e| e.to_string())?;
        let total: usize = groups.iter().map(|g| g.n).sum();
        if total != batch.n {
            return Err(format!("group sizes sum {total} != {}", batch.n));
        }
        if groups.iter().rev().skip(1).any(|g| g.n != spec.capacity()) {
            return Err("only the last group may be partial".into());
        }
        let mut rebuilt = Vec::new();
        let mut labels = Vec::new();
        for g in &groups {
            let d = decode_batch(g);
            rebuilt.extend_from_slice(&d.data);
            labels.extend_from_slice(&d.labels);
        }
        if rebuilt != batch.data || labels != batch.labels {
            return Err("content mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_dump_roundtrip() {
    check("dump serialization roundtrip", |rng| {
        let n = 1 + rng.gen_range(8);
        random_image_batch(rng, n)
    }, |batch| {
        let spec = EncodeSpec::new(Encoding::Lossless128, WordType::U64);
        let enc = encode_batch(batch, spec).map_err(|e| e.to_string())?;
        let back = dump::from_bytes(&dump::to_bytes(&enc)).map_err(|e| e.to_string())?;
        if decode_batch(&back) == *batch {
            Ok(())
        } else {
            Err("dump roundtrip mismatch".into())
        }
    });
}

#[test]
fn prop_sbs_batch_composition_matches_weights() {
    check_with("SBS composition", 48, 0xBA7C, |rng| {
        let classes = 2 + rng.gen_range(6);
        let per_class = 8 + rng.gen_range(24);
        let batch_size = 4 + rng.gen_range(28);
        let weights: Vec<f64> = (0..classes).map(|_| rng.f64() + 0.05).collect();
        (classes, per_class, batch_size, weights, rng.next_u64())
    }, |(classes, per_class, batch_size, weights, seed)| {
        let mut images = Vec::new();
        let mut labels = Vec::new();
        for c in 0..*classes {
            for _ in 0..*per_class {
                images.push(Image::zeros(4, 4, 1));
                labels.push(c);
            }
        }
        let d = MemDataset::new(images, labels, *classes);
        let specs: Vec<ClassSpec> = weights
            .iter()
            .map(|&w| ClassSpec::new(w, AugPolicy::none()))
            .collect();
        let mut s = SbsSampler::new(&d, *batch_size, specs, *seed)
            .map_err(|e| e.to_string())?;
        let counts = s.class_counts();
        if counts.iter().sum::<usize>() != *batch_size {
            return Err(format!("counts {counts:?} don't sum to {batch_size}"));
        }
        // realized batch matches the declared counts exactly
        let b = s.next_batch(&d);
        let mut realized = vec![0usize; *classes];
        for i in 0..b.n {
            realized[b.hard_label(i)] += 1;
        }
        if realized != counts {
            return Err(format!("realized {realized:?} != counts {counts:?}"));
        }
        // largest-remainder rounding: each count within 1 of exact share
        let total: f64 = weights.iter().sum();
        for (c, &cnt) in counts.iter().enumerate() {
            let exact = weights[c] / total * *batch_size as f64;
            if (cnt as f64 - exact).abs() > 1.0 {
                return Err(format!("class {c}: count {cnt} vs exact {exact:.2}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_loader_equals_sync() {
    check_with("parallel == sync loader", 16, 0x10AD, |rng| {
        (rng.next_u64(), 1 + rng.gen_range(6), rng.gen_range(5))
    }, |(seed, batches, num_workers)| {
        let make = |mode| {
            let d: Arc<dyn Dataset> = Arc::new(SynthCifar::cifar10(Split::Train, 200, 3));
            let sampler =
                SbsSampler::uniform(d.as_ref(), 8, AugPolicy::standard(), *seed).unwrap();
            let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::U64));
            EdLoader::new(d, sampler, spec, *batches, mode)
        };
        let mut a = make(LoaderMode::Synchronous);
        let mut b = make(LoaderMode::Parallel {
            prefetch_depth: 2,
            num_workers: *num_workers,
        });
        loop {
            match (a.next(), b.next()) {
                (None, None) => return Ok(()),
                (Some(BatchPayload::Encoded(x)), Some(BatchPayload::Encoded(y))) => {
                    for (gx, gy) in x.iter().zip(&y) {
                        if gx.words_u64 != gy.words_u64 || gx.labels != gy.labels {
                            return Err(format!("payload mismatch ({num_workers} workers)"));
                        }
                    }
                }
                _ => return Err("length/kind mismatch".into()),
            }
        }
    });
}

#[test]
fn prop_simulator_sc_never_exceeds_baseline_with_plan() {
    check_with("S-C(optimal) ≤ baseline peak", 24, 0x51D, |rng| {
        let models = ["tiny_cnn", "resnet18", "resnet50", "efficientnet_b0"];
        let model = models[rng.gen_range(models.len())];
        let h = [64usize, 128, 224][rng.gen_range(3)];
        let batch = 1 + rng.gen_range(32);
        (model.to_string(), h, batch)
    }, |(model, h, batch)| {
        let arch = arch_by_name(model, (*h, *h, 3), 10).ok_or("unknown arch")?;
        let base = simulate(&arch, Pipeline::BASELINE, *batch, &[]);
        let plan = plan_checkpoints(&arch, PlannerKind::Optimal, Pipeline::BASELINE, *batch);
        let sc = simulate(&arch, Pipeline::parse("sc").unwrap(), *batch, &plan.checkpoints);
        if sc.peak_bytes <= base.peak_bytes {
            Ok(())
        } else {
            Err(format!("sc {} > base {}", sc.peak_bytes, base.peak_bytes))
        }
    });
}

#[test]
fn prop_simulator_mp_halves_peak() {
    check_with("M-P ≈ half of baseline", 24, 0x3b, |rng| {
        let models = ["resnet18", "resnet34", "efficientnet_b0", "inception_v3"];
        let model = models[rng.gen_range(models.len())];
        let batch = 2 + rng.gen_range(30);
        (model.to_string(), batch)
    }, |(model, batch)| {
        let h = if model.contains("inception") { 299 } else { 224 };
        let arch = arch_by_name(model, (h, h, 3), 1000).ok_or("unknown arch")?;
        let base = simulate(&arch, Pipeline::BASELINE, *batch, &[]).peak_bytes as f64;
        let mp = simulate(&arch, Pipeline::parse("mp").unwrap(), *batch, &[]).peak_bytes as f64;
        let ratio = base / mp;
        if (1.7..=2.3).contains(&ratio) {
            Ok(())
        } else {
            Err(format!("ratio {ratio}"))
        }
    });
}

#[test]
fn prop_planner_checkpoints_valid_for_any_arch() {
    check_with("planner output validity", 32, 0x9999, |rng| {
        let names = optorch::models::all_arch_names();
        let name = names[rng.gen_range(names.len())].clone();
        let kinds = [
            PlannerKind::Uniform(1 + rng.gen_range(8)),
            PlannerKind::Sqrt,
            PlannerKind::Bottleneck(1 + rng.gen_range(6)),
        ];
        (name, kinds[rng.gen_range(3)], 1 + rng.gen_range(16))
    }, |(name, kind, batch)| {
        let h = if name.contains("inception_v3") { 299 } else { 96 };
        let arch = arch_by_name(name, (h, h, 3), 10).ok_or("unknown arch")?;
        let plan = plan_checkpoints(&arch, *kind, Pipeline::BASELINE, *batch);
        let mut sorted = plan.checkpoints.clone();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted != plan.checkpoints {
            return Err("not sorted/deduped".into());
        }
        if plan.checkpoints.iter().any(|&c| c >= arch.layers.len()) {
            return Err("checkpoint out of range".into());
        }
        if !(0.0..=1.0).contains(&plan.recompute_overhead) {
            return Err(format!("overhead {}", plan.recompute_overhead));
        }
        Ok(())
    });
}

#[test]
fn prop_synth_dataset_is_pure() {
    check("synthetic dataset purity", |rng| {
        (rng.next_u64(), rng.gen_range(500))
    }, |(seed, idx)| {
        let d = SynthCifar::cifar10(Split::Train, 500, *seed);
        let (a, la) = d.get(*idx);
        let (b, lb) = d.get(*idx);
        if a == b && la == lb {
            Ok(())
        } else {
            Err("dataset not pure".into())
        }
    });
}
