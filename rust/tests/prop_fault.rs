//! Property tests for the fault-injection harness and the recovery
//! machinery around it — the acceptance contract of the robustness PR:
//!
//! * a faulted run is **deterministic**: same seed + same `FaultSpec` ⇒
//!   the byte-identical batch stream and the same recovery counters, no
//!   matter how worker threads interleave;
//! * a worker kill inside the respawn budget is **invisible** in the
//!   stream: byte-identical to the fault-free run;
//! * the offload engine under link faults is deterministic and never
//!   leaves its held-buffer accounting inconsistent;
//! * the degradation ladder is deterministic and always lands on a real
//!   Pareto-frontier point — and every fault class either completes the
//!   run or surfaces a typed error, never a panic or a hang.

use optorch::data::augment::AugPolicy;
use optorch::data::dataset::Dataset;
use optorch::data::encode::{EncodeSpec, Encoding, WordType};
use optorch::data::loader::{dump, BatchPayload, EdLoader, LoaderMode};
use optorch::data::pool::BufferPool;
use optorch::data::sampler::SbsSampler;
use optorch::data::synth::{Split, SynthCifar};
use optorch::fault::{DegradeTrigger, FaultInjector, FaultSpec, LinkOutcome};
use optorch::memory::offload::{LinkFaults, OffloadEngine};
use optorch::memory::pipeline::{PlanError, PlanRequest};
use optorch::memory::planner::{pareto_frontier, DEFAULT_FRONTIER_LEVELS};
use optorch::models::arch_by_name;
use optorch::util::propcheck::check_with;
use std::sync::atomic::Ordering;
use std::sync::Arc;

fn loader_with(
    seed: u64,
    batches: usize,
    workers: usize,
    faults: Option<Arc<FaultInjector>>,
) -> EdLoader {
    let d: Arc<dyn Dataset> = Arc::new(SynthCifar::cifar10(Split::Train, 240, 9));
    let sampler = SbsSampler::uniform(
        d.as_ref(),
        16,
        AugPolicy::parse("hflip,crop4").unwrap(),
        seed,
    )
    .unwrap();
    EdLoader::with_faults(
        d,
        sampler,
        Some(EncodeSpec::new(Encoding::Base256, WordType::F64)),
        batches,
        LoaderMode::Parallel { prefetch_depth: 2, num_workers: workers },
        Arc::new(BufferPool::default()),
        faults,
        None,
    )
}

/// Serialize a payload to comparable bytes (dump covers words, offsets,
/// labels and geometry — the full shipped content).
fn payload_bytes(p: &BatchPayload) -> Vec<u8> {
    match p {
        BatchPayload::Raw { data, labels, n } => {
            let mut out = (*n as u64).to_le_bytes().to_vec();
            for v in data.iter().chain(labels) {
                out.extend_from_slice(&v.to_le_bytes());
            }
            out
        }
        BatchPayload::Encoded(groups) => {
            let mut out = Vec::new();
            for g in groups {
                out.extend_from_slice(&dump::to_bytes(g));
            }
            out
        }
    }
}

/// Drain a loader to `(payload bytes per step, respawns, corruptions,
/// error)`; a typed error ends the stream and rides back alongside
/// whatever arrived before it.
fn drain(mut l: EdLoader) -> (Vec<Vec<u8>>, u64, u64, Option<String>) {
    let mut out = Vec::new();
    let mut err = None;
    loop {
        match l.try_next() {
            Ok(Some(p)) => {
                out.push(payload_bytes(&p));
                l.recycle(p);
            }
            Ok(None) => break,
            Err(e) => {
                err = Some(e.to_string());
                break;
            }
        }
    }
    let stats = l.stats();
    let respawns = stats.respawns.load(Ordering::Relaxed);
    let corruptions = stats.corruptions_detected.load(Ordering::Relaxed);
    (out, respawns, corruptions, err)
}

/// Same seed + same `FaultSpec` ⇒ the identical batch stream and the
/// identical recovery counters, across reruns and worker counts.
#[test]
fn prop_faulted_streams_are_deterministic() {
    check_with("faulted stream determinism", 8, 0xFA17, |rng| {
        let batches = 4 + rng.gen_range(6);
        (
            rng.next_u64(),
            batches,
            rng.gen_range(batches),
            rng.gen_range(batches),
            1 + rng.gen_range(3),
        )
    }, |(seed, batches, panic_at, corrupt_at, workers)| {
        let spec = FaultSpec::parse(&format!(
            "seed={seed};worker-panic@{panic_at};corrupt@{corrupt_at}"
        ))
        .map_err(|e| e.to_string())?;
        let run = || {
            let inj = Some(Arc::new(FaultInjector::new(&spec)));
            drain(loader_with(*seed, *batches, *workers, inj))
        };
        let (a, a_respawns, a_corruptions, a_err) = run();
        let (b, b_respawns, b_corruptions, b_err) = run();
        if a_err.is_some() || b_err.is_some() {
            return Err(format!("unexpected typed error: {a_err:?} / {b_err:?}"));
        }
        if a != b {
            return Err(format!("streams diverged across reruns (workers={workers})"));
        }
        if a.len() != *batches {
            return Err(format!("faulted run yielded {} of {batches}", a.len()));
        }
        if (a_respawns, a_corruptions) != (b_respawns, b_corruptions) {
            return Err("recovery counters diverged across reruns".into());
        }
        if a_respawns != 1 || a_corruptions != 1 {
            return Err(format!(
                "expected 1 respawn + 1 corruption, saw {a_respawns} + {a_corruptions}"
            ));
        }
        Ok(())
    });
}

/// A worker kill inside the respawn budget must be invisible: the faulted
/// stream is byte-identical to the fault-free one.
#[test]
fn prop_worker_kill_is_invisible_in_the_stream() {
    check_with("worker kill ⇒ byte-identical stream", 8, 0xDEAD, |rng| {
        let batches = 4 + rng.gen_range(6);
        (rng.next_u64(), batches, rng.gen_range(batches), 1 + rng.gen_range(3))
    }, |(seed, batches, panic_at, workers)| {
        let (clean, _, _, clean_err) = drain(loader_with(*seed, *batches, *workers, None));
        if clean_err.is_some() {
            return Err(format!("fault-free run errored: {clean_err:?}"));
        }
        let spec = FaultSpec::parse(&format!("worker-panic@{panic_at}"))
            .map_err(|e| e.to_string())?;
        let inj = Some(Arc::new(FaultInjector::new(&spec)));
        let (faulted, respawns, _, err) = drain(loader_with(*seed, *batches, *workers, inj));
        if err.is_some() {
            return Err(format!("faulted run errored: {err:?}"));
        }
        if respawns != 1 {
            return Err(format!("expected exactly 1 respawn, saw {respawns}"));
        }
        if clean != faulted {
            return Err(format!(
                "stream changed under a worker kill at step {panic_at} (workers={workers})"
            ));
        }
        Ok(())
    });
}

/// Compose a spill plan the public way: probe the spilled floor with an
/// impossible budget, then plan at exactly that floor — which no pure
/// recompute plan can meet, so the outcome must carry a spill schedule.
fn floor_spill_plan() -> Result<optorch::memory::offload::SpillPlan, String> {
    let probe = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
        .batch(16)
        .memory_budget(1)
        .run()
        .err()
        .ok_or("a 1-byte budget cannot be satisfiable")?;
    let floor = match probe {
        PlanError::BudgetBelowSpilled(e) => e.min_device_bytes,
        other => return Err(format!("expected BudgetBelowSpilled, got {other:?}")),
    };
    PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
        .batch(16)
        .memory_budget(floor)
        .run()
        .map_err(|e| e.to_string())?
        .spill
        .ok_or_else(|| "floor budget must compose a spill plan".into())
}

/// The offload engine under probabilistic link faults: identical per-step
/// outcomes and stats across reruns, and every prefetch accounted to an
/// eviction that actually happened (a gave-up evict must not resurrect).
#[test]
fn prop_link_faulted_engine_is_deterministic() {
    let spill = floor_spill_plan().unwrap();
    check_with("link-faulted engine determinism", 10, 0x11AC, |rng| {
        (
            rng.next_u64(),
            rng.gen_range(50) as f64 / 100.0,   // fail_prob in [0, 0.5)
            1.0 + rng.gen_range(8) as f64,      // slowdown factor in [1, 9)
            8 + rng.gen_range(17),              // steps
        )
    }, |(seed, fail_prob, factor, steps)| {
        let link = LinkFaults {
            seed: *seed,
            fail_prob: *fail_prob,
            slow: (0.3, *factor),
            ..LinkFaults::default()
        };
        let run = || {
            let mut e = OffloadEngine::with_link_faults(&spill, link);
            let outcomes: Vec<Option<String>> = (0..*steps)
                .map(|_| e.try_step().err().map(|err| err.to_string()))
                .collect();
            (outcomes, e.stats())
        };
        let (ra, sa) = run();
        let (rb, sb) = run();
        if ra != rb {
            return Err("per-step outcomes diverged across reruns".into());
        }
        if sa != sb {
            return Err(format!("engine stats diverged: {sa:?} vs {sb:?}"));
        }
        if sa.prefetches > sa.evictions {
            return Err(format!(
                "{} prefetches for {} evictions: engine resurrected a failed evict",
                sa.prefetches, sa.evictions
            ));
        }
        Ok(())
    });
}

/// The degradation ladder: deterministic across reruns, and the chosen
/// plan is always a *real* Pareto-frontier point — even when the budget
/// is impossible and the ladder bottoms out in the heap fallback.
#[test]
fn prop_degradation_lands_on_a_frontier_point() {
    let arch = arch_by_name("tiny_cnn", (32, 32, 3), 10).unwrap();
    let frontier = pareto_frontier(
        &arch,
        optorch::config::Pipeline::BASELINE,
        16,
        DEFAULT_FRONTIER_LEVELS,
    );
    check_with("degradation ladder determinism", 12, 0xDE64, |rng| {
        // budgets from absurd (1 B) to generous — every regime of the ladder
        1u64 << rng.gen_range(31)
    }, |budget| {
        let request = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .batch(16)
            .memory_budget(*budget)
            .spill(false);
        let trigger = DegradeTrigger::BudgetShrink { from: None, to: *budget };
        let (out_a, rep_a) = request.run_degraded(trigger).map_err(|e| e.to_string())?;
        let (out_b, rep_b) = request.run_degraded(trigger).map_err(|e| e.to_string())?;
        if rep_a != rep_b || out_a.plan.checkpoints != out_b.plan.checkpoints {
            return Err("degraded outcome diverged across reruns".into());
        }
        if !frontier.iter().any(|p| p.checkpoints == out_a.plan.checkpoints) {
            return Err(format!(
                "budget {budget}: chosen checkpoints {:?} are not a frontier point",
                out_a.plan.checkpoints
            ));
        }
        if rep_a.met_budget && rep_a.device_total > *budget {
            return Err(format!(
                "met_budget claimed but device total {} exceeds {budget}",
                rep_a.device_total
            ));
        }
        Ok(())
    });
}

/// Belt-and-braces acceptance sweep: every fault class in one spec, on a
/// pool loader + the degradation ladder + the link fault model — the run
/// completes (or degrades with a typed report), never panics, never hangs.
#[test]
fn all_fault_classes_complete_or_degrade_typed() {
    let spec = FaultSpec::parse(
        "seed=5;worker-panic@2;corrupt@4;budget-shrink@6=1MiB;link-fail:0.2;link-slow:0.2,x4",
    )
    .unwrap();
    let inj = Arc::new(FaultInjector::new(&spec));

    // data path: panic + corruption recovered, full stream delivered
    let (stream, respawns, corruptions, err) =
        drain(loader_with(7, 10, 2, Some(inj.clone())));
    assert!(err.is_none(), "loader surfaced an error: {err:?}");
    assert_eq!(stream.len(), 10);
    assert_eq!(respawns, 1);
    assert_eq!(corruptions, 1);

    // budget shrink: the ladder absorbs it and reports what it took
    let to = inj.budget_shrink_due(6).expect("shrink event fires at step 6");
    assert_eq!(to, 1 << 20);
    let (outcome, report) = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
        .batch(16)
        .memory_budget(to)
        .run_degraded(DegradeTrigger::BudgetShrink { from: None, to })
        .expect("ladder must absorb any budget");
    assert!(!report.actions.is_empty() || report.met_budget, "{report:?}");
    assert!(outcome.plan.peak_bytes > 0);
    assert!(report.to_markdown().starts_with("degradation:"));

    // link faults: the injector's stateless draws drive the engine
    assert!(inj.has_link_faults());
    let saw_fault = (0..64u64).any(|step| inj.link_outcome(step, 0, 0) != LinkOutcome::Healthy);
    assert!(saw_fault, "p=0.4 combined over 64 draws must fault at least once");
}
