//! Property tests for the joint recompute/spill planner: joint is
//! feasible wherever the sequential plan→spill pipeline is and never
//! predicts a slower step; on chains short enough for the exhaustive
//! search it matches a brute-force sweep over every checkpoint subset;
//! planning is deterministic; param-gradient offload reaches budgets the
//! sequential pipeline reports `BudgetBelowSpilled` on; and a degraded
//! joint request still lands on a real Pareto-frontier point.

use optorch::config::Pipeline;
use optorch::fault::{DegradationAction, DegradeTrigger};
use optorch::memory::arena::{plan_arena, validate};
use optorch::memory::joint::{plan_joint, JOINT_EXHAUSTIVE_DEPTH};
use optorch::memory::offload::{
    plan_spill, select_for_budget, simulate_overlap, OverlapModel, SpillClass,
};
use optorch::memory::pipeline::{PlanError, PlanRequest};
use optorch::memory::planner::{pareto_frontier, PlannerKind, DEFAULT_FRONTIER_LEVELS};
use optorch::models::{ArchProfile, LayerKind, LayerProfile};
use optorch::util::propcheck::check_with;
use optorch::util::rng::Rng;

fn sc() -> Pipeline {
    Pipeline::parse("sc").unwrap()
}

/// Random chain. About a third of the chains are parameter-heavy (per-layer
/// param bytes rival activation bytes), so the sweep exercises both the
/// checkpoint-spill regime and the regime where resident gradients pin the
/// optimizer-step floor.
fn rand_chain(rng: &mut Rng, min_layers: usize, max_extra: usize) -> ArchProfile {
    let n = min_layers + rng.gen_range(max_extra + 1);
    let param_heavy = rng.gen_range(3) == 0;
    let layers = (0..n)
        .map(|i| {
            let h = 4 + rng.gen_range(5);
            let c = 32 + rng.gen_range(64);
            let out = (h * h * c) as u64;
            let params = if param_heavy {
                out * (4 + rng.gen_range(12)) as u64
            } else {
                (64 + rng.gen_range(1024)) as u64
            };
            LayerProfile {
                name: format!("l{i}"),
                kind: if param_heavy { LayerKind::Dense } else { LayerKind::Conv },
                out_shape: (h, h, c),
                act_elems: out * (1 + rng.gen_range(3)) as u64,
                params,
                flops_per_image: (1 + rng.gen_range(900)) as u64 * 10_000,
            }
        })
        .collect();
    ArchProfile {
        name: "rand_joint_chain".into(),
        input: (1 + rng.gen_range(6), 1 + rng.gen_range(6), 3),
        layers,
    }
}

/// Parameter-heavy chain (same shape as the joint module's unit-test
/// profile): per-layer param bytes ≈ batch·act bytes, so the sequential
/// floor sits at the optimizer step where only gradient offload helps.
fn param_heavy_chain(depth: usize) -> ArchProfile {
    let layers = (0..depth)
        .map(|i| {
            let out = (8 * 8 * 64) as u64;
            LayerProfile {
                name: format!("fc{i}"),
                kind: LayerKind::Dense,
                out_shape: (8, 8, 64),
                act_elems: out * 2,
                params: out * 16,
                flops_per_image: 2_000_000,
            }
        })
        .collect();
    ArchProfile { name: format!("fc_chain{depth}"), input: (8, 8, 3), layers }
}

/// Reference budget scale: the packed total of the all-checkpointed plan.
fn packed_total(arch: &ArchProfile, batch: usize) -> u64 {
    let cps: Vec<usize> = (0..arch.layers.len().saturating_sub(1)).collect();
    plan_arena(arch, sc(), batch, &cps).1.total_bytes()
}

#[test]
fn prop_joint_dominates_sequential_everywhere() {
    check_with(
        "joint is feasible wherever sequential is, never predicts a slower \
         step, and reports a floor at or below the sequential one",
        60,
        0x10A1,
        |rng| {
            let arch = rand_chain(rng, 6, 14);
            let batch = 1 + rng.gen_range(8);
            let frac = 15 + rng.gen_range(96); // 15..=110 percent
            let budget = (packed_total(&arch, batch) as u128 * frac as u128 / 100).max(1) as u64;
            let bw = [1e6, 1e8, 12e9][rng.gen_range(3)];
            (arch, batch, budget, 1 + rng.gen_range(3), bw)
        },
        |(arch, batch, budget, lookahead, bw)| {
            let model = OverlapModel { host_bw_bytes_per_sec: *bw, device_flops_per_sec: 2e12 };
            let seq = select_for_budget(arch, sc(), *batch, *budget, *lookahead, &model);
            let joint = plan_joint(arch, sc(), *batch, *budget, *lookahead, &model, true);
            match (seq, joint) {
                (Ok(s), Ok(j)) => {
                    if j.overlap.predicted_step_secs > s.overlap.predicted_step_secs {
                        return Err(format!(
                            "joint {} slower than sequential {}",
                            j.overlap.predicted_step_secs, s.overlap.predicted_step_secs
                        ));
                    }
                    if j.spill.device_total() > *budget {
                        return Err(format!(
                            "joint device total {} exceeds budget {budget}",
                            j.spill.device_total()
                        ));
                    }
                    validate(&j.spill.lifetimes, &j.spill.layout)
                        .map_err(|e| format!("joint resident layout invalid: {e}"))?;
                    Ok(())
                }
                (Ok(_), Err(e)) => {
                    Err(format!("joint infeasible where sequential fits: {e}"))
                }
                (Err(_), Ok(j)) => {
                    // gradient offload reaching below the sequential floor
                    if j.spill.device_total() > *budget {
                        return Err(format!(
                            "rescue plan {} exceeds budget {budget}",
                            j.spill.device_total()
                        ));
                    }
                    Ok(())
                }
                (Err(s), Err(j)) => {
                    if j.min_device_bytes > s.min_device_bytes {
                        return Err(format!(
                            "joint floor {} above sequential floor {}",
                            j.min_device_bytes, s.min_device_bytes
                        ));
                    }
                    Ok(())
                }
            }
        },
    );
}

#[test]
fn prop_joint_matches_brute_force_on_short_chains() {
    check_with(
        "chains within the exhaustive depth: joint without grad offload \
         equals the brute-force minimum over every checkpoint subset \
         composed via plan_spill, and with grad offload never exceeds it",
        25,
        0x10A2,
        |rng| {
            // 4..=JOINT_EXHAUSTIVE_DEPTH layers so plan_joint enumerates
            // every subset rather than the frontier
            let arch = rand_chain(rng, 4, JOINT_EXHAUSTIVE_DEPTH - 4);
            let batch = 1 + rng.gen_range(8);
            let frac = 25 + rng.gen_range(86); // 25..=110 percent
            let budget = (packed_total(&arch, batch) as u128 * frac as u128 / 100).max(1) as u64;
            let bw = [1e8, 12e9][rng.gen_range(2)];
            (arch, batch, budget, bw)
        },
        |(arch, batch, budget, bw)| {
            let model = OverlapModel { host_bw_bytes_per_sec: *bw, device_flops_per_sec: 2e12 };
            let n = arch.layers.len();
            let mut brute: Option<f64> = None;
            for mask in 0u32..(1u32 << (n - 1)) {
                let cps: Vec<usize> = (0..n - 1).filter(|&i| mask >> i & 1 == 1).collect();
                if let Ok(sp) = plan_spill(arch, sc(), *batch, &cps, *budget, 2) {
                    let rep = simulate_overlap(arch, *batch, &sp, &model);
                    let t = rep.predicted_step_secs;
                    brute = Some(brute.unwrap_or(f64::INFINITY).min(t));
                }
            }
            let seq_only = plan_joint(arch, sc(), *batch, *budget, 2, &model, false);
            match (brute, &seq_only) {
                (Some(b), Ok(j)) => {
                    if j.overlap.predicted_step_secs != b {
                        return Err(format!(
                            "joint (no grads) {} ≠ brute-force minimum {b}",
                            j.overlap.predicted_step_secs
                        ));
                    }
                }
                (Some(_), Err(e)) => {
                    return Err(format!("joint infeasible where brute force found a plan: {e}"))
                }
                (None, Ok(_)) => {
                    return Err("joint (no grads) feasible where brute force found none".into())
                }
                (None, Err(_)) => {}
            }
            let with_grads = plan_joint(arch, sc(), *batch, *budget, 2, &model, true);
            if let (Some(b), Ok(j)) = (brute, &with_grads) {
                if j.overlap.predicted_step_secs > b {
                    return Err(format!(
                        "joint with grad offload {} slower than brute force {b}",
                        j.overlap.predicted_step_secs
                    ));
                }
            }
            if brute.is_some() && with_grads.is_err() {
                return Err("grad offload lost feasibility the sequential orders had".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_joint_planning_is_deterministic() {
    check_with(
        "same inputs → identical placement, spill steps, layout and timing",
        40,
        0x10A3,
        |rng| {
            let arch = rand_chain(rng, 6, 12);
            let batch = 1 + rng.gen_range(8);
            let frac = 30 + rng.gen_range(71);
            let budget = (packed_total(&arch, batch) as u128 * frac as u128 / 100).max(1) as u64;
            (arch, batch, budget)
        },
        |(arch, batch, budget)| {
            let model = OverlapModel::default();
            let a = plan_joint(arch, sc(), *batch, *budget, 2, &model, true);
            let b = plan_joint(arch, sc(), *batch, *budget, 2, &model, true);
            match (a, b) {
                (Ok(x), Ok(y)) => {
                    if x.plan.checkpoints != y.plan.checkpoints {
                        return Err("placements differ across identical runs".into());
                    }
                    if x.spill.steps != y.spill.steps {
                        return Err("spill steps differ across identical runs".into());
                    }
                    if x.spill.layout.offsets != y.spill.layout.offsets {
                        return Err("layouts differ across identical runs".into());
                    }
                    if x.overlap.predicted_step_secs != y.overlap.predicted_step_secs {
                        return Err("predicted step times differ".into());
                    }
                    Ok(())
                }
                (Err(x), Err(y)) => {
                    if x == y {
                        Ok(())
                    } else {
                        Err("infeasibility errors differ".into())
                    }
                }
                _ => Err("feasibility verdict differs across identical runs".into()),
            }
        },
    );
}

/// The ISSUE's acceptance test, at the facade level: a budget one byte
/// below the sequential floor makes the default pipeline return
/// `PlanError::BudgetBelowSpilled`, and the *same request* with
/// `PlannerKind::Joint` plans it — with the win coming from param-gradient
/// spills.
#[test]
fn facade_joint_reaches_a_budget_sequential_reports_infeasible() {
    let arch = param_heavy_chain(12);
    let model = OverlapModel::default();
    let seq_floor = select_for_budget(&arch, sc(), 16, 1, 2, &model)
        .expect_err("a 1-byte budget cannot be feasible")
        .min_device_bytes;
    let budget = seq_floor - 1;
    let base = PlanRequest::for_arch(arch.clone())
        .pipeline(sc())
        .batch(16)
        .memory_budget(budget);
    match base.clone().run() {
        Err(PlanError::BudgetBelowSpilled(e)) => assert!(e.min_device_bytes > budget),
        other => panic!("expected BudgetBelowSpilled from the sequential pipeline, got {other:?}"),
    }
    let out = base
        .planner(PlannerKind::Joint)
        .run()
        .expect("the joint planner reaches below the sequential floor");
    assert!(out.device_peak_packed() <= budget);
    let spill = out.spill.as_ref().expect("the rescue must come from spilling");
    assert!(
        spill.steps.iter().any(|s| s.class == SpillClass::ParamGrad),
        "expected param-gradient spills in the rescue plan: {:?}",
        spill.steps
    );
}

/// `run_degraded` on a joint request: an impossible budget walks the
/// ladder to the heap fallback, and the chosen plan is a real point of
/// the Pareto frontier — not an ad-hoc placement.
#[test]
fn degraded_joint_request_lands_on_a_frontier_point() {
    let arch = param_heavy_chain(10);
    let req = PlanRequest::for_arch(arch.clone())
        .pipeline(sc())
        .batch(16)
        .planner(PlannerKind::Joint)
        .memory_budget(1);
    assert!(req.run().is_err(), "a 1-byte budget cannot be met even jointly");
    let (out, report) = req
        .run_degraded(DegradeTrigger::BudgetShrink { from: None, to: 1 })
        .expect("the degradation ladder absorbs an impossible budget");
    assert!(!report.met_budget);
    assert_eq!(report.actions, vec![DegradationAction::HeapFallbackArena]);
    let frontier = pareto_frontier(&arch, sc(), 16, DEFAULT_FRONTIER_LEVELS);
    assert!(
        frontier.iter().any(|p| p.checkpoints == out.plan.checkpoints),
        "degraded plan {:?} is not a frontier point",
        out.plan.checkpoints
    );
}
