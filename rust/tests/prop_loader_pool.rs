//! Property tests for the multi-worker E-D data path:
//!
//! * encode→decode is bit-exact for every `(encoding, word type, n)` with
//!   `n = 1..=capacity` — including the `*_into` buffer-reusing variants;
//! * the worker-pool loader is deterministic: for the same seed, every
//!   worker count yields the byte-identical payload sequence of the
//!   classic single-producer path (`num_workers = 0`);
//! * steady-state epochs are allocation-free as measured by the
//!   [`BufferPool`] counters.

use optorch::data::augment::AugPolicy;
use optorch::data::dataset::Dataset;
use optorch::data::encode::{
    decode_batch, encode_batch, encode_batch_into, EncodeSpec, EncodedBatch, Encoding, WordType,
};
use optorch::data::image::ImageBatch;
use optorch::data::loader::{dump, BatchPayload, EdLoader, LoaderMode};
use optorch::data::pool::BufferPool;
use optorch::data::sampler::SbsSampler;
use optorch::data::synth::{Split, SynthCifar};
use optorch::util::propcheck::check_with;
use optorch::util::rng::Rng;
use std::sync::Arc;

fn random_batch(rng: &mut Rng, n: usize, h: usize, w: usize, c: usize) -> ImageBatch {
    let mut b = ImageBatch::zeros(n, h, w, c, 10);
    for v in b.data.iter_mut() {
        *v = (rng.next_u32() & 0xff) as u8;
    }
    for i in 0..n {
        let cls = rng.gen_range(10);
        b.label_mut(i)[cls] = 1.0;
    }
    b
}

/// Exhaustive over the whole (encoding, word, n) grid, randomized over
/// image contents/shapes: the roundtrip must be bit-exact at every fill
/// level, and the buffer-reusing encoder must agree with the allocating
/// one even when its shell carries stale state from a previous batch.
#[test]
fn prop_roundtrip_bit_exact_across_fill_levels() {
    check_with("roundtrip n=1..=capacity", 24, 0xE0C0DE, |rng| {
        (rng.next_u64(), 1 + rng.gen_range(12), 1 + rng.gen_range(12), 1 + rng.gen_range(3))
    }, |(seed, h, w, c)| {
        let mut rng = Rng::new(*seed);
        let mut shell: Option<EncodedBatch> = None;
        for encoding in [Encoding::Base256, Encoding::Lossless128] {
            for word in [WordType::U64, WordType::F64] {
                let spec = EncodeSpec::new(encoding, word);
                for n in 1..=spec.capacity() {
                    let b = random_batch(&mut rng, n, *h, *w, *c);
                    let enc = encode_batch(&b, spec).map_err(|e| e.to_string())?;
                    if decode_batch(&enc) != b {
                        return Err(format!("{spec:?} n={n}: roundtrip mismatch"));
                    }
                    // reuse one shell across every spec/n — worst case for
                    // stale-buffer bugs
                    let mut sh = shell.take().unwrap_or_else(|| EncodedBatch::empty(spec));
                    encode_batch_into(&b, spec, &mut sh).map_err(|e| e.to_string())?;
                    if sh.words_u64 != enc.words_u64
                        || sh.words_f64 != enc.words_f64
                        || sh.offsets != enc.offsets
                        || sh.labels != enc.labels
                    {
                        return Err(format!("{spec:?} n={n}: into-variant diverged"));
                    }
                    shell = Some(sh);
                }
            }
        }
        Ok(())
    });
}

fn loader_with(
    seed: u64,
    batches: usize,
    spec: Option<EncodeSpec>,
    mode: LoaderMode,
    pool: Arc<BufferPool>,
) -> EdLoader {
    let d: Arc<dyn Dataset> = Arc::new(SynthCifar::cifar10(Split::Train, 240, 9));
    let sampler = SbsSampler::uniform(
        d.as_ref(),
        16,
        AugPolicy::parse("hflip,crop4,cutout4").unwrap(),
        seed,
    )
    .unwrap();
    EdLoader::with_pool(d, sampler, spec, batches, mode, pool)
}

/// Serialize a payload to comparable bytes (dump covers words, offsets,
/// labels and geometry — the full shipped content).
fn payload_bytes(p: &BatchPayload) -> Vec<u8> {
    match p {
        BatchPayload::Raw { data, labels, n } => {
            let mut out = (*n as u64).to_le_bytes().to_vec();
            for v in data.iter().chain(labels) {
                out.extend_from_slice(&v.to_le_bytes());
            }
            out
        }
        BatchPayload::Encoded(groups) => {
            let mut out = Vec::new();
            for g in groups {
                out.extend_from_slice(&dump::to_bytes(g));
            }
            out
        }
    }
}

/// The determinism contract the trainer relies on: same seed ⇒ same batch
/// order and payload bytes, no matter how many workers race to produce.
#[test]
fn prop_worker_pool_is_deterministic_vs_single_producer() {
    check_with("pool == single producer", 8, 0xD17E, |rng| {
        (rng.next_u64(), 2 + rng.gen_range(8), rng.bool(0.5))
    }, |(seed, batches, encoded)| {
        let spec = encoded.then(|| EncodeSpec::new(Encoding::Base256, WordType::F64));
        let reference: Vec<Vec<u8>> = {
            let mut l = loader_with(
                *seed,
                *batches,
                spec,
                LoaderMode::Parallel { prefetch_depth: 2, num_workers: 0 },
                Arc::new(BufferPool::default()),
            );
            let mut out = Vec::new();
            while let Some(p) = l.next() {
                out.push(payload_bytes(&p));
                l.recycle(p);
            }
            out
        };
        if reference.len() != *batches {
            return Err(format!("reference yielded {} of {batches}", reference.len()));
        }
        for workers in [1, 2, 4, 8] {
            let mut l = loader_with(
                *seed,
                *batches,
                spec,
                LoaderMode::Parallel { prefetch_depth: 2, num_workers: workers },
                Arc::new(BufferPool::default()),
            );
            let mut step = 0;
            while let Some(p) = l.next() {
                if payload_bytes(&p) != reference[step] {
                    return Err(format!("workers={workers}: step {step} diverged"));
                }
                l.recycle(p);
                step += 1;
            }
            if step != *batches {
                return Err(format!("workers={workers}: yielded {step} of {batches}"));
            }
        }
        Ok(())
    });
}

/// Zero-allocation steady state, synchronous mode (deterministic): after a
/// two-batch warmup the pool must serve every request from recycled
/// buffers — across epoch boundaries too, because the trainer shares one
/// pool across all its epoch-scoped loaders.
#[test]
fn sync_epochs_are_allocation_free_at_steady_state() {
    let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::F64));
    let pool = Arc::new(BufferPool::default());
    // epoch 0: warmup
    let mut warm = loader_with(5, 4, spec, LoaderMode::Synchronous, pool.clone());
    while let Some(p) = warm.next() {
        warm.recycle(p);
    }
    drop(warm);
    let warm_allocs = pool.allocs();
    assert!(warm_allocs > 0, "warmup must have populated the pool");
    // epochs 1..3: must not allocate at all
    for epoch in 1..4 {
        let mut l = loader_with(5 + epoch, 6, spec, LoaderMode::Synchronous, pool.clone());
        while let Some(p) = l.next() {
            l.recycle(p);
        }
        assert_eq!(
            pool.allocs(),
            warm_allocs,
            "epoch {epoch} allocated on the hot path"
        );
    }
    assert!(pool.reuses() > warm_allocs, "steady state must run on reuses");
}

/// The same property for the worker pool. Thread timing decides how many
/// payloads are in flight at once, so the bound is the worst-case
/// in-flight count rather than exactly zero: the loader's permit gate caps
/// payloads at `prefetch_depth + num_workers`, plus one in the consumer's
/// hand — once that many buffer sets exist, further epochs must stop
/// allocating.
#[test]
fn worker_pool_allocation_is_bounded_by_in_flight_slots() {
    let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::F64));
    let (depth, workers, batches) = (2usize, 3usize, 20usize);
    let mode = LoaderMode::Parallel { prefetch_depth: depth, num_workers: workers };
    let pool = Arc::new(BufferPool::default());
    // warm epoch
    let mut l = loader_with(11, batches, spec, mode, pool.clone());
    while let Some(p) = l.next() {
        l.recycle(p);
    }
    drop(l);
    let warm_allocs = pool.allocs();
    // a payload is a shell + 3 groups × (words_u64 scratch, words_f64, labels)
    let bufs_per_payload = 1 + 3 * 3;
    // the gate's hard bound + the consumer's hand + one slot of slack
    let max_in_flight = depth + workers + 2;
    for epoch in 0..3 {
        let mut l = loader_with(13 + epoch, batches, spec, mode, pool.clone());
        while let Some(p) = l.next() {
            l.recycle(p);
        }
        drop(l);
        let bound = (max_in_flight * bufs_per_payload) as u64;
        assert!(
            pool.allocs() <= warm_allocs + bound,
            "epoch {epoch}: allocs {} exceed warm {warm_allocs} + bound {bound}",
            pool.allocs()
        );
    }
    assert!(pool.reuses() > 0);
}
