//! Property tests for the observability layer: memory-watermark
//! invariants across the planning grid, metrics-ring bounds, and the
//! `--memlog` CSV round trip.

use optorch::config::Pipeline;
use optorch::memory::outcome::PlanOutcome;
use optorch::memory::pipeline::PlanRequest;
use optorch::obs::{MemTimeline, MemWatermarkReport, MemlogObserved, MetricsHub, StepSample};

/// The planning grid the watermark properties sweep: small inputs keep
/// the DP fast, the two models cover shallow and deep schedules.
const GRID: &[(&str, (usize, usize, usize), usize)] =
    &[("tiny_cnn", (32, 32, 3), 10), ("resnet18", (64, 64, 3), 10)];

fn plan(model: &str, input: (usize, usize, usize), classes: usize, batch: usize) -> PlanOutcome {
    PlanRequest::for_model(model, input, classes)
        .pipeline(Pipeline::parse("ed+sc").expect("pipeline"))
        .batch(batch)
        .run()
        .expect("plan")
}

#[test]
fn observed_high_water_never_exceeds_predicted_peak() {
    for &(model, input, classes) in GRID {
        for batch in [4usize, 8, 16] {
            let out = plan(model, input, classes, batch);
            let tl = MemTimeline::from_outcome(&out).expect("timeline");
            // The replayed series can never exceed the DP peak…
            for i in 0..tl.len() {
                assert!(
                    tl.base_bytes() + tl.live_at(i) <= out.plan.peak_bytes,
                    "{model} batch {batch}: step {i} live {} over predicted peak {}",
                    tl.live_at(i),
                    out.plan.peak_bytes
                );
            }
            // …and the packed slab (what the runtime reserves) bounds it too.
            assert!(tl.observed_peak_bytes() <= out.device_peak_packed());
        }
    }
}

#[test]
fn non_spill_plans_touch_the_predicted_peak() {
    for &(model, input, classes) in GRID {
        for batch in [4usize, 8, 16] {
            let out = plan(model, input, classes, batch);
            assert!(!out.is_spill(), "unbudgeted plans never spill");
            let tl = MemTimeline::from_outcome(&out).expect("timeline");
            // Exactness: the observed peak equals the DP prediction, and
            // the series actually reaches its high-water mark on ≥1 step.
            assert_eq!(
                tl.observed_peak_bytes(),
                out.plan.peak_bytes,
                "{model} batch {batch}"
            );
            let hw = tl.slab_high_water_bytes();
            assert!(
                (0..tl.len()).any(|i| tl.live_at(i) == hw),
                "{model} batch {batch}: series never reaches its own max"
            );
        }
    }
}

#[test]
fn spill_plans_stay_under_packed_and_predict_host_floor() {
    for &(model, input, classes) in GRID {
        let base = plan(model, input, classes, 8);
        let packed = base.device_peak_packed();
        // Probe downward for a budget the spill composition still meets
        // (the exact floor depends on the arch).
        let budgeted = [95u64, 90, 80, 70].iter().find_map(|pct| {
            PlanRequest::for_model(model, input, classes)
                .pipeline(Pipeline::parse("ed+sc").expect("pipeline"))
                .batch(8)
                .memory_budget(packed * pct / 100)
                .run()
                .ok()
        });
        let Some(out) = budgeted else { continue };
        let tl = MemTimeline::from_outcome(&out).expect("timeline");
        assert!(tl.observed_peak_bytes() <= out.device_peak_packed(), "{model}");
        if out.is_spill() {
            let host = tl.predicted_host_peak_bytes().expect("spilling plan predicts a floor");
            assert!(host > 0, "{model}: spilled but predicted 0 host bytes");
        }
    }
}

#[test]
fn watermark_report_is_exact_for_non_spill_runs() {
    let out = plan("tiny_cnn", (32, 32, 3), 10, 8);
    let tl = MemTimeline::from_outcome(&out).expect("timeline");
    let rep = MemWatermarkReport::from_observed(&tl, 0, 17).expect("report");
    assert_eq!(rep.observed_peak_bytes, rep.predicted_peak_bytes);
    assert!(rep.rel_err_pct().abs() < 1e-9);
    assert!(rep.predicted_host_peak_bytes.is_none());
}

#[test]
fn metrics_ring_drops_and_counts_instead_of_growing() {
    for capacity in [1usize, 2, 7, 64] {
        let hub = MetricsHub::with_capacity(capacity);
        let total = capacity * 3 + 5;
        for i in 0..total {
            hub.record_step(StepSample {
                step: i as u64,
                slab_high_water_bytes: i as u64,
                ..Default::default()
            });
        }
        assert_eq!(hub.len(), capacity, "capacity {capacity}");
        assert_eq!(hub.dropped(), (total - capacity) as u64);
        assert_eq!(hub.steps(), total as u64);
        // drops never stale the gauges: latest + maxima track every sample
        assert_eq!(hub.latest().expect("latest").step, total as u64 - 1);
        assert_eq!(hub.max_slab_high_water_bytes(), total as u64 - 1);
    }
}

#[test]
fn memlog_roundtrip_preserves_watermarks() {
    // Deterministic pseudo-random walk (no RNG dependency).
    let mut x = 0x9E37_79B9u64;
    let samples: Vec<StepSample> = (0..200u64)
        .map(|i| {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            StepSample {
                step: i,
                slab_high_water_bytes: x % 1_000_000,
                host_resident_bytes: (x >> 20) % 500_000,
                scratch_used_bytes: x % 4096,
                scratch_high_water_bytes: 4096,
                link_retry_backlog: x % 3,
                loader_queue_depth: x % 5,
                degrade_rung: 0,
                step_secs: 0.001 + (x % 100) as f64 * 1e-5,
            }
        })
        .collect();
    let expected_slab = samples.iter().map(|s| s.slab_high_water_bytes).max().unwrap();
    let expected_host = samples.iter().map(|s| s.host_resident_bytes).max().unwrap();
    let mut csv = String::from(StepSample::csv_header());
    csv.push('\n');
    for s in &samples {
        csv.push_str(&s.to_csv_row());
        csv.push('\n');
    }
    let obs = MemlogObserved::parse_csv(&csv).expect("parse");
    assert_eq!(obs.steps, 200);
    assert_eq!(obs.slab_high_water_bytes, expected_slab);
    assert_eq!(obs.host_peak_bytes, expected_host);
    // and the offline report agrees with the online one
    let out = plan("tiny_cnn", (32, 32, 3), 10, 8);
    let tl = MemTimeline::from_outcome(&out).expect("timeline");
    let offline = obs.against(&tl).expect("report");
    assert_eq!(offline.steps, 200);
    assert_eq!(offline.observed_slab_high_water_bytes, expected_slab);
    assert_eq!(offline.observed_host_peak_bytes, expected_host);
}
