//! Property tests for the host-spill offload engine: a spilled layout
//! always fits the budget or the planner returns the typed
//! `InfeasibleBudget` error; prefetches never land after the first
//! backward use; evict/prefetch pairing is exact; plans are deterministic.

use optorch::config::Pipeline;
use optorch::memory::arena::{validate, TensorClass};
use optorch::memory::offload::{
    plan_spill, simulate_overlap, OverlapModel, SpillPlan, TransferKind,
};
use optorch::models::{ArchProfile, LayerKind, LayerProfile};
use optorch::util::propcheck::check_with;
use optorch::util::rng::Rng;

fn sc() -> Pipeline {
    Pipeline::parse("sc").unwrap()
}

/// Random checkpoint-heavy chain: uniform-ish layer widths and small
/// parameter counts, so resident checkpoints (not one layer's backward
/// working set) dominate the packed slab — the regime host-spill targets.
fn rand_chain(rng: &mut Rng, min_layers: usize, max_extra: usize) -> ArchProfile {
    let n = min_layers + rng.gen_range(max_extra + 1);
    let layers = (0..n)
        .map(|i| {
            let h = 4 + rng.gen_range(5);
            let c = 32 + rng.gen_range(64);
            let out = (h * h * c) as u64;
            LayerProfile {
                name: format!("l{i}"),
                kind: LayerKind::Conv,
                out_shape: (h, h, c),
                act_elems: out * (1 + rng.gen_range(3)) as u64,
                params: (64 + rng.gen_range(1024)) as u64,
                flops_per_image: (1 + rng.gen_range(900)) as u64 * 10_000,
            }
        })
        .collect();
    ArchProfile {
        name: "rand_offload_chain".into(),
        input: (1 + rng.gen_range(6), 1 + rng.gen_range(6), 3),
        layers,
    }
}

/// A random plan with plenty of checkpoints (offload needs cold tensors
/// to work with): each interior layer stored with probability 3/4.
fn rand_plan(rng: &mut Rng, n: usize) -> Vec<usize> {
    (0..n.saturating_sub(1)).filter(|_| rng.gen_range(4) != 0).collect()
}

fn spill_for(
    arch: &ArchProfile,
    batch: usize,
    cps: &[usize],
    budget: u64,
    lookahead: usize,
) -> Result<SpillPlan, optorch::memory::offload::InfeasibleBudget> {
    plan_spill(arch, sc(), batch, cps, budget, lookahead)
}

#[test]
fn prop_spill_fits_the_budget_or_is_typed_infeasible() {
    check_with(
        "plan_spill: resident total ≤ budget, or InfeasibleBudget with a floor above it",
        80,
        0x0FF1,
        |rng| {
            let arch = rand_chain(rng, 8, 16);
            let n = arch.layers.len();
            let cps = rand_plan(rng, n);
            let batch = 1 + rng.gen_range(8);
            // budget anywhere from far below the floor to above the packed
            // total — exercised via a random fraction of the unspilled pack
            let (_, layout) = optorch::memory::arena::plan_arena(&arch, sc(), batch, &cps);
            let frac = 1 + rng.gen_range(120); // 1..=120 percent
            let budget = (layout.total_bytes() as u128 * frac as u128 / 100) as u64;
            let lookahead = 1 + rng.gen_range(4);
            (arch, cps, batch, budget.max(1), lookahead)
        },
        |(arch, cps, batch, budget, lookahead)| {
            match spill_for(arch, *batch, cps, *budget, *lookahead) {
                Ok(spill) => {
                    if spill.device_total() > *budget {
                        return Err(format!(
                            "plan claims to fit but {} > {budget}",
                            spill.device_total()
                        ));
                    }
                    validate(&spill.lifetimes, &spill.layout)
                        .map_err(|e| format!("resident layout invalid: {e}"))?;
                    Ok(())
                }
                Err(e) => {
                    if e.min_device_bytes <= *budget {
                        return Err(format!(
                            "InfeasibleBudget floor {} is not above the budget {budget}",
                            e.min_device_bytes
                        ));
                    }
                    Ok(())
                }
            }
        },
    );
}

#[test]
fn prop_prefetch_never_lands_after_first_backward_use() {
    check_with(
        "every spilled tensor: evict < prefetch < need, and the simulated \
         prefetch completes by the (stall-adjusted) need step",
        60,
        0x0FF2,
        |rng| {
            let arch = rand_chain(rng, 10, 14);
            let n = arch.layers.len();
            let cps: Vec<usize> = (0..n - 1).collect(); // checkpoint-rich
            let batch = 1 + rng.gen_range(8);
            let (_, layout) = optorch::memory::arena::plan_arena(&arch, sc(), batch, &cps);
            // 50–90% of the packed total: tight enough to force spilling
            let frac = 50 + rng.gen_range(41);
            let budget = (layout.total_bytes() as u128 * frac as u128 / 100) as u64;
            let bw = [1e6, 1e8, 12e9][rng.gen_range(3)];
            (arch, cps, batch, budget, 1 + rng.gen_range(3), bw)
        },
        |(arch, cps, batch, budget, lookahead, bw)| {
            let spill = match spill_for(arch, *batch, cps, *budget, *lookahead) {
                Ok(s) => s,
                Err(_) => return Ok(()), // infeasible budgets covered elsewhere
            };
            for s in &spill.steps {
                if !(s.evict_step < s.prefetch_step && s.prefetch_step < s.need_step) {
                    return Err(format!("window not ordered: {s:?}"));
                }
                if s.need_step - s.prefetch_step > *lookahead {
                    return Err(format!("prefetch issued beyond the lookahead window: {s:?}"));
                }
            }
            let model = OverlapModel {
                host_bw_bytes_per_sec: *bw,
                device_flops_per_sec: 2e12,
            };
            let rep = simulate_overlap(arch, *batch, &spill, &model);
            for s in &spill.steps {
                let done = rep
                    .transfers
                    .iter()
                    .find(|t| t.kind == TransferKind::Prefetch && t.layer == s.layer)
                    .map(|t| t.done_sec)
                    .ok_or_else(|| format!("no prefetch simulated for layer {}", s.layer))?;
                // lateness is charged as stall, so the step start already
                // accounts for the wait — data is on-device when needed
                if done > rep.step_start_secs[s.need_step] + 1e-9 {
                    return Err(format!(
                        "layer {}: prefetch done {done} after need-step start {}",
                        s.layer, rep.step_start_secs[s.need_step]
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_evict_prefetch_pairing_is_exact() {
    check_with(
        "each spilled layer appears once; resident lifetimes carry exactly \
         two checkpoint windows per spilled layer and one otherwise",
        60,
        0x0FF3,
        |rng| {
            let arch = rand_chain(rng, 10, 14);
            let n = arch.layers.len();
            let cps = rand_plan(rng, n);
            let batch = 1 + rng.gen_range(8);
            let (_, layout) = optorch::memory::arena::plan_arena(&arch, sc(), batch, &cps);
            let frac = 40 + rng.gen_range(56);
            let budget = (layout.total_bytes() as u128 * frac as u128 / 100) as u64;
            (arch, cps, batch, budget)
        },
        |(arch, cps, batch, budget)| {
            let spill = match spill_for(arch, *batch, cps, *budget, 2) {
                Ok(s) => s,
                Err(_) => return Ok(()),
            };
            let mut spilled: Vec<usize> = spill.steps.iter().map(|s| s.layer).collect();
            let before = spilled.len();
            spilled.sort_unstable();
            spilled.dedup();
            if spilled.len() != before {
                return Err("a layer was spilled more than once".into());
            }
            let n = arch.layers.len();
            for layer in 0..n {
                let windows = spill
                    .lifetimes
                    .tensors
                    .iter()
                    .filter(|t| t.class == TensorClass::Checkpoint && t.layer == layer)
                    .count();
                let expect = if spilled.binary_search(&layer).is_ok() { 2 } else { 1 };
                // non-checkpointed layers have zero checkpoint windows
                if windows != 0 && windows != expect {
                    return Err(format!(
                        "layer {layer}: {windows} checkpoint windows, expected 0 or {expect}"
                    ));
                }
                if spilled.binary_search(&layer).is_ok() && windows != 2 {
                    return Err(format!("spilled layer {layer} has {windows} windows"));
                }
            }
            // byte conservation: spilled bytes = Σ per-step bytes, and each
            // step's bytes match the layer's boundary output
            let total: u64 = spill.steps.iter().map(|s| s.bytes).sum();
            if total != spill.spilled_bytes {
                return Err(format!("spilled_bytes {} ≠ Σ steps {total}", spill.spilled_bytes));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_spill_planning_is_deterministic() {
    check_with(
        "same inputs → byte-identical spill plan, layout and timeline",
        40,
        0x0FF4,
        |rng| {
            let arch = rand_chain(rng, 8, 16);
            let n = arch.layers.len();
            let cps = rand_plan(rng, n);
            let batch = 1 + rng.gen_range(8);
            let (_, layout) = optorch::memory::arena::plan_arena(&arch, sc(), batch, &cps);
            let frac = 40 + rng.gen_range(70);
            let budget = (layout.total_bytes() as u128 * frac as u128 / 100) as u64;
            (arch, cps, batch, budget)
        },
        |(arch, cps, batch, budget)| {
            let a = spill_for(arch, *batch, cps, *budget, 2);
            let b = spill_for(arch, *batch, cps, *budget, 2);
            match (a, b) {
                (Ok(x), Ok(y)) => {
                    if x.steps != y.steps {
                        return Err("spill steps differ across identical runs".into());
                    }
                    if x.layout.offsets != y.layout.offsets
                        || x.layout.slab_bytes != y.layout.slab_bytes
                    {
                        return Err("resident layouts differ across identical runs".into());
                    }
                    let m = OverlapModel::default();
                    let ra = simulate_overlap(arch, *batch, &x, &m);
                    let rb = simulate_overlap(arch, *batch, &y, &m);
                    if ra.stall_secs != rb.stall_secs
                        || ra.predicted_step_secs != rb.predicted_step_secs
                    {
                        return Err("overlap simulation diverged".into());
                    }
                    Ok(())
                }
                (Err(x), Err(y)) => {
                    if x == y {
                        Ok(())
                    } else {
                        Err("infeasibility errors differ".into())
                    }
                }
                _ => Err("feasibility verdict differs across identical runs".into()),
            }
        },
    );
}
