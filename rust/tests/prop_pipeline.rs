//! Property tests for the unified memory-pipeline facade: a
//! `PlanRequest::run()` must be **bit-identical** to the legacy
//! free-function composition it replaced — plan, packed slab, spill
//! pairing and predicted step seconds — across arch × pipeline × budget
//! sweeps, and its JSON rendering must be deterministic.

use optorch::config::Pipeline;
use optorch::memory::arena::plan_arena;
use optorch::memory::offload::{plan_spill, select_for_budget, OverlapModel};
use optorch::memory::pipeline::{PlanError, PlanRequest};
use optorch::memory::planner::{
    plan_checkpoints, plan_for_budget_packed, PlannerKind, DEFAULT_FRONTIER_LEVELS,
};
use optorch::models::{arch_by_name, ArchProfile, LayerKind, LayerProfile};
use optorch::util::propcheck::check_with;
use optorch::util::rng::Rng;

/// Random checkpoint-heavy chain (same family as the offload property
/// tests): uniform-ish widths so budgets below the pure floor stay
/// spillable.
fn rand_chain(rng: &mut Rng) -> ArchProfile {
    let n = 8 + rng.gen_range(10);
    let layers = (0..n)
        .map(|i| {
            let h = 4 + rng.gen_range(5);
            let c = 32 + rng.gen_range(64);
            let out = (h * h * c) as u64;
            LayerProfile {
                name: format!("l{i}"),
                kind: LayerKind::Conv,
                out_shape: (h, h, c),
                act_elems: out * (1 + rng.gen_range(3)) as u64,
                params: (64 + rng.gen_range(1024)) as u64,
                flops_per_image: (1 + rng.gen_range(900)) as u64 * 10_000,
            }
        })
        .collect();
    ArchProfile { name: "rand_pipeline_chain".into(), input: (8, 8, 3), layers }
}

fn rand_arch(rng: &mut Rng) -> ArchProfile {
    match rng.gen_range(3) {
        0 => arch_by_name("tiny_cnn", (32, 32, 3), 10).unwrap(),
        1 => arch_by_name("resnet18", (64, 64, 3), 10).unwrap(),
        _ => rand_chain(rng),
    }
}

fn rand_pipeline(rng: &mut Rng) -> Pipeline {
    let spec = ["sc", "ed+sc", "ed+mp+sc"][rng.gen_range(3)];
    Pipeline::parse(spec).unwrap()
}

fn rand_batch(rng: &mut Rng) -> usize {
    [4usize, 8, 16][rng.gen_range(3)]
}

fn rand_kind(rng: &mut Rng) -> PlannerKind {
    match rng.gen_range(4) {
        0 => PlannerKind::Optimal,
        1 => PlannerKind::Sqrt,
        2 => PlannerKind::Uniform(1 + rng.gen_range(5)),
        _ => PlannerKind::Bottleneck(1 + rng.gen_range(4)),
    }
}

#[derive(Clone, Debug)]
struct Case {
    arch: ArchProfile,
    pipeline: Pipeline,
    batch: usize,
    kind: PlannerKind,
}

fn gen_case(rng: &mut Rng) -> Case {
    Case {
        arch: rand_arch(rng),
        pipeline: rand_pipeline(rng),
        batch: rand_batch(rng),
        kind: rand_kind(rng),
    }
}

#[test]
fn facade_matches_the_legacy_unbudgeted_composition() {
    check_with("facade == plan_checkpoints + plan_arena", 48, 0x91BE, gen_case, |c| {
        let outcome = PlanRequest::for_arch(c.arch.clone())
            .pipeline(c.pipeline)
            .batch(c.batch)
            .planner(c.kind)
            .run()
            .map_err(|e| format!("facade errored: {e}"))?;
        let legacy = plan_checkpoints(&c.arch, c.kind, c.pipeline, c.batch);
        if outcome.plan.checkpoints != legacy.checkpoints {
            return Err(format!(
                "checkpoints {:?} != legacy {:?}",
                outcome.plan.checkpoints, legacy.checkpoints
            ));
        }
        if outcome.plan.peak_bytes != legacy.peak_bytes {
            return Err("peak bytes diverged".into());
        }
        if outcome.plan.recompute_overhead != legacy.recompute_overhead {
            return Err("recompute overhead diverged".into());
        }
        if outcome.memory.peak_bytes != legacy.peak_bytes {
            return Err("staged memory report peak != plan peak".into());
        }
        let (lt, layout) = plan_arena(&c.arch, c.pipeline, c.batch, &legacy.checkpoints);
        let flayout = outcome.layout().ok_or("facade staged no layout")?;
        if flayout.offsets != layout.offsets
            || flayout.slab_bytes != layout.slab_bytes
            || flayout.base_bytes != layout.base_bytes
        {
            return Err("packed layout diverged".into());
        }
        if outcome.lifetimes().map(|l| l.tensors.len()) != Some(lt.tensors.len()) {
            return Err("lifetimes diverged".into());
        }
        if outcome.device_peak_packed() != layout.total_bytes() {
            return Err("device_peak_packed != packed total".into());
        }
        Ok(())
    });
}

#[test]
fn facade_budget_matches_select_for_budget_exactly() {
    check_with("facade budget == select_for_budget", 24, 0xB0D6E7, gen_case, |c| {
        // Budgets straddling the pure floor: comfortable, tight, and
        // sub-floor (spilling), derived from the optimal plan's pack.
        let opt = plan_checkpoints(&c.arch, PlannerKind::Optimal, c.pipeline, c.batch);
        let packed = plan_arena(&c.arch, c.pipeline, c.batch, &opt.checkpoints).1.total_bytes();
        for pct in [130u64, 95, 60] {
            let budget = packed * pct / 100;
            let facade = PlanRequest::for_arch(c.arch.clone())
                .pipeline(c.pipeline)
                .batch(c.batch)
                .memory_budget(budget)
                .run();
            let legacy = select_for_budget(
                &c.arch,
                c.pipeline,
                c.batch,
                budget,
                2,
                &OverlapModel::default(),
            );
            match (facade, legacy) {
                (Ok(f), Ok(l)) => {
                    if f.plan.checkpoints != l.plan.checkpoints {
                        return Err(format!("{pct}%: chose different plans"));
                    }
                    let fs = f.spill.as_ref().ok_or("budgeted outcome lacks spill")?;
                    if fs.steps != l.spill.steps {
                        return Err(format!("{pct}%: spill pairing diverged"));
                    }
                    if fs.layout.offsets != l.spill.layout.offsets {
                        return Err(format!("{pct}%: resident offsets diverged"));
                    }
                    let fo = f.overlap.as_ref().ok_or("budgeted outcome lacks overlap")?;
                    if fo.predicted_step_secs != l.overlap.predicted_step_secs
                        || fo.stall_secs != l.overlap.stall_secs
                    {
                        return Err(format!("{pct}%: predicted step secs diverged"));
                    }
                    if f.predicted_step_secs() != Some(l.overlap.predicted_step_secs) {
                        return Err(format!("{pct}%: accessor diverged"));
                    }
                    if f.is_spill() != !l.spill.steps.is_empty() {
                        return Err(format!("{pct}%: is_spill diverged"));
                    }
                }
                (Err(PlanError::BudgetBelowSpilled(fe)), Err(le)) => {
                    if fe != le {
                        return Err(format!("{pct}%: infeasibility floors diverged"));
                    }
                }
                (f, l) => {
                    return Err(format!(
                        "{pct}%: feasibility diverged (facade ok: {}, legacy ok: {})",
                        f.is_ok(),
                        l.is_ok()
                    ))
                }
            }
        }
        Ok(())
    });
}

#[test]
fn facade_spill_off_matches_plan_for_budget_packed() {
    check_with("facade spill(false) == plan_for_budget_packed", 24, 0x9AC4ED, gen_case, |c| {
        let opt = plan_checkpoints(&c.arch, PlannerKind::Optimal, c.pipeline, c.batch);
        let packed = plan_arena(&c.arch, c.pipeline, c.batch, &opt.checkpoints).1.total_bytes();
        for pct in [140u64, 100, 55] {
            let budget = packed * pct / 100;
            let facade = PlanRequest::for_arch(c.arch.clone())
                .pipeline(c.pipeline)
                .batch(c.batch)
                .memory_budget(budget)
                .spill(false)
                .run();
            let legacy = plan_for_budget_packed(&c.arch, c.pipeline, c.batch, budget);
            match (facade, legacy) {
                (Ok(f), Ok((plan, _, layout))) => {
                    if f.plan.checkpoints != plan.checkpoints {
                        return Err(format!("{pct}%: chose different plans"));
                    }
                    if f.layout().map(|l| l.offsets.clone()) != Some(layout.offsets) {
                        return Err(format!("{pct}%: layouts diverged"));
                    }
                    if f.spill.is_some() {
                        return Err(format!("{pct}%: spill staged with spilling off"));
                    }
                }
                (Err(PlanError::BudgetBelowPacked(fe)), Err(le)) => {
                    if fe != le {
                        return Err(format!("{pct}%: packed floors diverged"));
                    }
                }
                (f, l) => {
                    return Err(format!(
                        "{pct}%: feasibility diverged (facade ok: {}, legacy ok: {})",
                        f.is_ok(),
                        l.is_ok()
                    ))
                }
            }
        }
        Ok(())
    });
}

#[test]
fn facade_explicit_checkpoints_match_plan_spill() {
    check_with("facade with_checkpoints == plan_spill", 24, 0x5B111, gen_case, |c| {
        let n = c.arch.layers.len();
        let full: Vec<usize> = (0..n.saturating_sub(1)).collect();
        let packed = plan_arena(&c.arch, c.pipeline, c.batch, &full).1.total_bytes();
        for pct in [110u64, 70] {
            let budget = packed * pct / 100;
            let facade = PlanRequest::for_arch(c.arch.clone())
                .pipeline(c.pipeline)
                .batch(c.batch)
                .with_checkpoints(full.clone())
                .memory_budget(budget)
                .spill_lookahead(3)
                .run();
            let legacy = plan_spill(&c.arch, c.pipeline, c.batch, &full, budget, 3);
            match (facade, legacy) {
                (Ok(f), Ok(l)) => {
                    let fs = f.spill.as_ref().ok_or("budgeted outcome lacks spill")?;
                    if fs.steps != l.steps {
                        return Err(format!("{pct}%: spill pairing diverged"));
                    }
                    if fs.layout.offsets != l.layout.offsets
                        || fs.layout.slab_bytes != l.layout.slab_bytes
                    {
                        return Err(format!("{pct}%: resident layouts diverged"));
                    }
                    if fs.spilled_bytes != l.spilled_bytes
                        || fs.host_peak_bytes != l.host_peak_bytes
                    {
                        return Err(format!("{pct}%: spill byte accounting diverged"));
                    }
                }
                (Err(PlanError::BudgetBelowSpilled(fe)), Err(le)) => {
                    if fe != le {
                        return Err(format!("{pct}%: floors diverged"));
                    }
                }
                (f, l) => {
                    return Err(format!(
                        "{pct}%: feasibility diverged (facade ok: {}, legacy ok: {})",
                        f.is_ok(),
                        l.is_ok()
                    ))
                }
            }
        }
        Ok(())
    });
}

#[test]
fn json_rendering_is_deterministic_across_runs() {
    check_with("outcome JSON is deterministic", 16, 0x15014D, gen_case, |c| {
        let req = PlanRequest::for_arch(c.arch.clone())
            .pipeline(c.pipeline)
            .batch(c.batch)
            .planner(c.kind)
            .frontier(true)
            .frontier_levels(DEFAULT_FRONTIER_LEVELS);
        let a = req.run().map_err(|e| e.to_string())?.to_json().to_string();
        let b = req.run().map_err(|e| e.to_string())?.to_json().to_string();
        if a != b {
            return Err("same request rendered different JSON".into());
        }
        optorch::util::json::Json::parse(&a)
            .map_err(|e| format!("JSON does not re-parse: {e}"))?;
        Ok(())
    });
}
