//! Property tests for the checkpoint-planner stack: the zero-allocation
//! peak evaluator must agree exactly with the timeline simulator, the DP
//! must match brute-force enumeration on small chains, and Pareto
//! frontiers must be strictly non-dominated and correctly anchored.

use optorch::config::Pipeline;
use optorch::memory::peak::PeakEvaluator;
use optorch::memory::planner::{
    pareto_frontier, plan_checkpoints, plan_for_budget, PlannerKind,
};
use optorch::memory::simulator::simulate;
use optorch::models::{ArchProfile, LayerKind, LayerProfile};
use optorch::util::propcheck::check_with;
use optorch::util::rng::Rng;

/// Random heterogeneous chain respecting the planner invariant
/// `act_elems ≥ out_elems` (every registry profile stores at least its
/// boundary tensor — see `memory::peak` docs).
fn rand_chain(rng: &mut Rng, max_layers: usize) -> ArchProfile {
    let n = 1 + rng.gen_range(max_layers);
    let layers = (0..n)
        .map(|i| {
            let h = 1 + rng.gen_range(6);
            let c = 1 + rng.gen_range(48);
            let out = (h * h * c) as u64;
            LayerProfile {
                name: format!("l{i}"),
                kind: LayerKind::Dense,
                out_shape: (h, h, c),
                act_elems: out * (1 + rng.gen_range(4)) as u64,
                params: rng.gen_range(5_000) as u64,
                flops_per_image: (1 + rng.gen_range(900)) as u64 * 1_000,
            }
        })
        .collect();
    ArchProfile {
        name: "rand_chain".into(),
        input: (1 + rng.gen_range(6), 1 + rng.gen_range(6), 3),
        layers,
    }
}

#[test]
fn prop_peak_evaluator_matches_simulator() {
    check_with(
        "evaluator peak == simulate peak",
        96,
        0xA11C,
        |rng| {
            let arch = rand_chain(rng, 14);
            let n = arch.layers.len();
            // random plan, deliberately including out-of-range indices
            let plan: Vec<usize> = (0..n + 2).filter(|_| rng.gen_range(2) == 1).collect();
            let pipes = ["b", "sc", "mp", "ed+sc", "ed+mp+sc"];
            let pipe = pipes[rng.gen_range(pipes.len())].to_string();
            (arch, plan, pipe, 1 + rng.gen_range(12))
        },
        |(arch, plan, pipe, batch)| {
            let p = Pipeline::parse(pipe).unwrap();
            let mut ev = PeakEvaluator::new(arch, p, *batch);
            let got = ev.peak(plan);
            let want = simulate(arch, p, *batch, plan).peak_bytes;
            if got == want {
                Ok(())
            } else {
                Err(format!("evaluator {got} != simulate {want} [{pipe}]"))
            }
        },
    );
}

#[test]
fn prop_dp_matches_bruteforce_on_small_chains() {
    check_with(
        "DP optimal == exhaustive enumeration (n ≤ 14)",
        48,
        0xD9,
        |rng| (rand_chain(rng, 14), 1 + rng.gen_range(8)),
        |(arch, batch)| {
            let n = arch.layers.len();
            let sc = Pipeline::parse("sc").unwrap();
            let mut ev = PeakEvaluator::new(arch, sc, *batch);
            let mut best = u64::MAX;
            for mask in 0u32..(1u32 << (n - 1)) {
                let cps: Vec<usize> = (0..n - 1).filter(|i| mask >> i & 1 == 1).collect();
                best = best.min(ev.peak(&cps));
            }
            let opt = plan_checkpoints(arch, PlannerKind::Optimal, Pipeline::BASELINE, *batch);
            if opt.peak_bytes == best {
                Ok(())
            } else {
                Err(format!("dp {} != brute force {best}", opt.peak_bytes))
            }
        },
    );
}

#[test]
fn prop_frontier_strictly_pareto_and_anchored() {
    check_with(
        "frontier sorted, non-dominated, anchored",
        48,
        0xF40,
        |rng| (rand_chain(rng, 20), 1 + rng.gen_range(8)),
        |(arch, batch)| {
            let frontier = pareto_frontier(arch, Pipeline::BASELINE, *batch, 12);
            if frontier.is_empty() {
                return Err("empty frontier".into());
            }
            for w in frontier.windows(2) {
                if w[0].peak_bytes >= w[1].peak_bytes {
                    return Err(format!(
                        "peaks not strictly increasing: {} then {}",
                        w[0].peak_bytes, w[1].peak_bytes
                    ));
                }
                if w[0].recompute_overhead <= w[1].recompute_overhead {
                    return Err(format!(
                        "overheads not strictly decreasing: {} then {}",
                        w[0].recompute_overhead, w[1].recompute_overhead
                    ));
                }
            }
            let opt = plan_checkpoints(arch, PlannerKind::Optimal, Pipeline::BASELINE, *batch);
            if frontier[0].peak_bytes != opt.peak_bytes {
                return Err(format!(
                    "frontier[0] {} != exact min peak {}",
                    frontier[0].peak_bytes, opt.peak_bytes
                ));
            }
            if frontier.last().unwrap().recompute_overhead != 0.0 {
                return Err("frontier does not end at the zero-recompute plan".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_budget_selection_fits_and_is_cheapest() {
    check_with(
        "plan_for_budget fits and is cheapest-time",
        48,
        0xB4D6,
        |rng| (rand_chain(rng, 16), 1 + rng.gen_range(8), rng.next_u64()),
        |(arch, batch, budget_roll)| {
            let frontier = pareto_frontier(arch, Pipeline::BASELINE, *batch, 12);
            let lo = frontier.first().unwrap().peak_bytes;
            let hi = frontier.last().unwrap().peak_bytes;
            let budget = lo + budget_roll % (hi - lo + 1);
            let plan = plan_for_budget(arch, Pipeline::BASELINE, *batch, budget)?;
            if plan.peak_bytes > budget {
                return Err(format!("plan peak {} exceeds budget {budget}", plan.peak_bytes));
            }
            for p in &frontier {
                if p.peak_bytes <= budget && p.recompute_overhead < plan.recompute_overhead {
                    return Err("a cheaper-time frontier point also fits the budget".into());
                }
            }
            if plan_for_budget(arch, Pipeline::BASELINE, *batch, lo - 1).is_ok() {
                return Err("accepted a budget below the minimum achievable peak".into());
            }
            Ok(())
        },
    );
}
