//! Property tests for the serving subsystem: the forward-only lifetime
//! replay (subset of training, exactness against the evaluator's forward
//! peak), forward-vs-training slab dominance across the whole model
//! registry, and plan-cache determinism.

use optorch::config::Pipeline;
use optorch::memory::arena::{pack, validate, Lifetimes};
use optorch::memory::peak::PeakEvaluator;
use optorch::memory::pipeline::{PlanMode, PlanRequest};
use optorch::models::{all_arch_names, arch_by_name};
use optorch::serve::{PlanCache, PlanKey};

/// The per-model input convention of `optorch models`: inception needs
/// its native resolution, the CIFAR-class models take 32², everything
/// else a mid-size input.
fn input_for(name: &str) -> ((usize, usize, usize), usize) {
    if name.contains("inception_v3") {
        ((299, 299, 3), 1000)
    } else if name.contains("mini") || name.contains("lite") || name == "tiny_cnn" {
        ((32, 32, 3), 10)
    } else {
        ((64, 64, 3), 10)
    }
}

/// Every inference tensor is covered by a training tensor of the same
/// layer: the forward-only replay never invents liveness the training
/// schedule did not already have — it only drops the backward tail.
#[test]
fn inference_lifetimes_are_a_subset_of_training_lifetimes() {
    let pipeline = Pipeline::parse("b").expect("pipeline");
    for name in ["tiny_cnn", "resnet18", "effnet_lite"] {
        let (input, classes) = input_for(name);
        let arch = arch_by_name(name, input, classes).expect("registry model");
        for batch in [1usize, 8] {
            let ev = PeakEvaluator::new(&arch, pipeline, batch);
            let train = Lifetimes::extract(&ev, &[]);
            let infer = Lifetimes::extract_infer(&ev);
            assert!(
                infer.base_bytes <= train.base_bytes,
                "{name} batch {batch}: infer base {} over train base {}",
                infer.base_bytes,
                train.base_bytes
            );
            for t in &infer.tensors {
                let covered = train.tensors.iter().any(|tr| {
                    tr.layer == t.layer
                        && tr.bytes >= t.bytes
                        && tr.start <= t.start
                        && tr.end >= t.end
                });
                assert!(
                    covered,
                    "{name} batch {batch}: infer tensor {:?} not covered by any \
                     training tensor at the same layer",
                    t
                );
            }
        }
    }
}

/// `base + max_live == forward peak`, exactly, for every registry arch:
/// the forward-only replay is an accounting identity, not an estimate.
#[test]
fn infer_replay_is_exact_against_the_forward_peak() {
    let pipeline = Pipeline::parse("b").expect("pipeline");
    for name in all_arch_names() {
        let (input, classes) = input_for(&name);
        let arch = arch_by_name(&name, input, classes).expect("registry model");
        for batch in [1usize, 8] {
            let ev = PeakEvaluator::new(&arch, pipeline, batch);
            let lt = Lifetimes::extract_infer(&ev);
            assert_eq!(
                lt.base_bytes + lt.max_live_bytes(),
                ev.forward_peak(),
                "{name} batch {batch}: infer replay disagrees with forward peak"
            );
        }
    }
}

/// The packed forward-only slab never exceeds the packed training slab,
/// for every registry arch × batch — the headline claim of serving from
/// forward-only plans.
#[test]
fn forward_slab_never_exceeds_training_slab_across_the_registry() {
    let pipeline = Pipeline::parse("b").expect("pipeline");
    for name in all_arch_names() {
        let (input, classes) = input_for(&name);
        let arch = arch_by_name(&name, input, classes).expect("registry model");
        for batch in [1usize, 8] {
            let ev = PeakEvaluator::new(&arch, pipeline, batch);
            let infer = Lifetimes::extract_infer(&ev);
            let train = Lifetimes::extract(&ev, &[]);
            let infer_layout = pack(&infer);
            let train_layout = pack(&train);
            validate(&infer, &infer_layout).expect("valid forward packing");
            assert!(
                infer_layout.total_bytes() <= train_layout.total_bytes(),
                "{name} batch {batch}: forward slab {} over training slab {}",
                infer_layout.total_bytes(),
                train_layout.total_bytes()
            );
        }
    }
}

/// Through the full planning facade (DP, packing, the works): the
/// `PlanMode::Infer` outcome's device peak is strictly below the
/// training outcome's for real models, and its predicted step time is
/// pure forward compute.
#[test]
fn infer_plans_strictly_undercut_training_plans() {
    for (name, batch) in [("tiny_cnn", 16usize), ("resnet18", 8)] {
        let (input, classes) = input_for(name);
        let infer = PlanRequest::for_model(name, input, classes)
            .batch(batch)
            .mode(PlanMode::Infer)
            .run()
            .expect("infer plan");
        let train = PlanRequest::for_model(name, input, classes)
            .batch(batch)
            .run()
            .expect("train plan");
        assert!(
            infer.device_peak_packed() < train.device_peak_packed(),
            "{name} batch {batch}: infer slab {} !< train slab {}",
            infer.device_peak_packed(),
            train.device_peak_packed()
        );
        assert!(infer.predicted_step_secs().expect("forward step time") > 0.0);
    }
}

/// The LRU plan cache is deterministic: the same lookup sequence yields
/// the same hit/miss/eviction counts and the same cached outcomes, and
/// eviction order follows recency exactly.
#[test]
fn plan_cache_hits_and_evictions_are_deterministic() {
    let run_sequence = || {
        let mut cache = PlanCache::new(2);
        let mut peaks = Vec::new();
        // batches 4, 8, 4 (hit), 16 (evicts 8), 8 (replans)
        for batch in [4usize, 8, 4, 16, 8] {
            let key = PlanKey {
                arch: "tiny_cnn".to_string(),
                batch,
                budget: None,
                host_bw: 1 << 30,
            };
            let out = cache
                .get_or_insert_with(&key, || {
                    PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
                        .batch(batch)
                        .host_bw(1 << 30)
                        .mode(PlanMode::Infer)
                        .run()
                })
                .expect("plan");
            peaks.push(out.device_peak_packed());
        }
        (cache.hits(), cache.misses(), cache.evictions(), peaks)
    };
    let (hits, misses, evictions, peaks) = run_sequence();
    assert_eq!((hits, misses, evictions), (1, 4, 2), "4,8,4(hit),16(evict 8),8(evict 4)");
    assert_eq!(peaks[0], peaks[2], "the cache hit returned the same outcome");
    assert_eq!(peaks[1], peaks[4], "a replanned key reproduces its outcome");
    assert_eq!(run_sequence(), (hits, misses, evictions, peaks), "bit-identical rerun");
}
