//! Property tests for the structured tracing layer — the observability
//! PR's acceptance contract:
//!
//! * spans on any one track are **properly nested** (stack discipline:
//!   two spans either nest or are disjoint, never partially overlap);
//! * the drained track order and per-track event-name sequences are
//!   **deterministic** for a fixed seed (timestamps vary, structure
//!   does not);
//! * tracing is **invisible to the data**: the batch stream under
//!   `--faults` with worker respawns is byte-identical traced vs
//!   untraced, and the respawn shows up as a trace instant;
//! * the hot path never allocates at steady state: a full per-thread
//!   buffer drops (and counts) events instead of growing.

use optorch::data::augment::AugPolicy;
use optorch::data::dataset::Dataset;
use optorch::data::encode::{EncodeSpec, Encoding, WordType};
use optorch::data::loader::{dump, BatchPayload, EdLoader, LoaderMode};
use optorch::data::pool::BufferPool;
use optorch::data::sampler::SbsSampler;
use optorch::data::synth::{Split, SynthCifar};
use optorch::fault::{FaultInjector, FaultSpec};
use optorch::trace::{EventKind, TraceLog, Track, Tracer};
use optorch::util::json::Json;
use optorch::util::propcheck::check_with;
use std::sync::Arc;

fn loader_with(
    seed: u64,
    batches: usize,
    workers: usize,
    faults: Option<Arc<FaultInjector>>,
    tracer: Tracer,
) -> EdLoader {
    let d: Arc<dyn Dataset> = Arc::new(SynthCifar::cifar10(Split::Train, 240, 9));
    let sampler = SbsSampler::uniform(
        d.as_ref(),
        16,
        AugPolicy::parse("hflip,crop4").unwrap(),
        seed,
    )
    .unwrap();
    EdLoader::with_observability(
        d,
        sampler,
        Some(EncodeSpec::new(Encoding::Base256, WordType::F64)),
        batches,
        LoaderMode::Parallel { prefetch_depth: 2, num_workers: workers },
        Arc::new(BufferPool::default()),
        faults,
        None,
        tracer,
    )
}

fn payload_bytes(p: &BatchPayload) -> Vec<u8> {
    match p {
        BatchPayload::Raw { data, labels, n } => {
            let mut out = (*n as u64).to_le_bytes().to_vec();
            for v in data.iter().chain(labels) {
                out.extend_from_slice(&v.to_le_bytes());
            }
            out
        }
        BatchPayload::Encoded(groups) => {
            let mut out = Vec::new();
            for g in groups {
                out.extend_from_slice(&dump::to_bytes(g));
            }
            out
        }
    }
}

fn drain(mut l: EdLoader) -> Result<Vec<Vec<u8>>, String> {
    let mut out = Vec::new();
    loop {
        match l.try_next() {
            Ok(Some(p)) => {
                out.push(payload_bytes(&p));
                l.recycle(p);
            }
            Ok(None) => break,
            Err(e) => return Err(e.to_string()),
        }
    }
    Ok(out)
}

/// Spans on one track must use stack discipline: sorted by (start asc,
/// end desc), every span either contains or is disjoint from the one
/// below it — a span reaching past its enclosing span is an error.
fn assert_nested(track: &Track) -> Result<(), String> {
    let mut spans: Vec<(u64, u64)> = track
        .events
        .iter()
        .filter_map(|e| match e.kind {
            EventKind::Span { dur_ns } => Some((e.ts_ns, e.ts_ns + dur_ns)),
            _ => None,
        })
        .collect();
    spans.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.cmp(&a.1)));
    let mut stack: Vec<(u64, u64)> = Vec::new();
    for (start, end) in spans {
        while let Some(&(_, top_end)) = stack.last() {
            if start >= top_end {
                stack.pop();
            } else {
                break;
            }
        }
        if let Some(&(top_start, top_end)) = stack.last() {
            if end > top_end {
                return Err(format!(
                    "track '{}': span [{start}, {end}) partially overlaps [{top_start}, {top_end})",
                    track.name
                ));
            }
        }
        stack.push((start, end));
    }
    Ok(())
}

/// The name sequence of every track, in drained (deterministic) order.
fn name_shape(log: &TraceLog) -> Vec<(String, Vec<String>)> {
    log.tracks
        .iter()
        .map(|t| {
            (
                t.name.clone(),
                t.events.iter().map(|e| e.name.to_string()).collect(),
            )
        })
        .collect()
}

/// Every track of a traced pool run — workers, planner, supervisor,
/// sequencer — keeps stack discipline, under faults included.
#[test]
fn prop_tracks_are_properly_nested() {
    check_with("span nesting per track", 8, 0x7A0E, |rng| {
        let batches = 4 + rng.gen_range(6);
        (rng.next_u64(), batches, 1 + rng.gen_range(3), rng.gen_range(batches))
    }, |(seed, batches, workers, corrupt_at)| {
        let spec = FaultSpec::parse(&format!("seed={seed};corrupt@{corrupt_at}"))
            .map_err(|e| e.to_string())?;
        let inj = Some(Arc::new(FaultInjector::new(&spec)));
        let tracer = Tracer::enabled();
        drain(loader_with(*seed, *batches, *workers, inj, tracer.clone()))?;
        let log = tracer.drain();
        if log.event_count() == 0 {
            return Err("traced run recorded no events".into());
        }
        for track in &log.tracks {
            assert_nested(track)?;
        }
        Ok(())
    });
}

/// The nesting checker itself must reject a partially-overlapping pair
/// (the API can express misuse; the property test is what forbids it).
#[test]
fn nesting_checker_rejects_partial_overlap() {
    let tr = Tracer::enabled();
    let mut t = tr.thread("bad");
    let outer = t.begin();
    std::thread::sleep(std::time::Duration::from_millis(2));
    let inner = t.begin();
    std::thread::sleep(std::time::Duration::from_millis(2));
    t.end_span("outer", "x", outer); // ends while "inner" still open
    std::thread::sleep(std::time::Duration::from_millis(2));
    t.end_span("inner", "x", inner); // reaches past its enclosing span
    t.finish();
    let log = tr.drain();
    assert!(assert_nested(&log.tracks[0]).is_err(), "checker accepted partial overlap");
}

/// Single-producer mode: same seed ⇒ the same tracks with the same
/// event-name sequences, run after run (timestamps differ; shape not).
#[test]
fn prop_trace_shape_is_deterministic_for_fixed_seed() {
    check_with("trace shape determinism", 8, 0xD5EE, |rng| {
        let batches = 3 + rng.gen_range(5);
        (rng.next_u64(), batches, rng.gen_range(batches))
    }, |(seed, batches, corrupt_at)| {
        let spec = FaultSpec::parse(&format!("seed={seed};corrupt@{corrupt_at}"))
            .map_err(|e| e.to_string())?;
        let run = || -> Result<_, String> {
            let inj = Some(Arc::new(FaultInjector::new(&spec)));
            let tracer = Tracer::enabled();
            let stream = drain(loader_with(*seed, *batches, 0, inj, tracer.clone()))?;
            Ok((stream, name_shape(&tracer.drain())))
        };
        let (stream_a, shape_a) = run()?;
        let (stream_b, shape_b) = run()?;
        if stream_a != stream_b {
            return Err("payload streams diverged across reruns".into());
        }
        if shape_a != shape_b {
            return Err(format!("trace shape diverged:\n{shape_a:?}\nvs\n{shape_b:?}"));
        }
        if !shape_a.iter().any(|(_, names)| names.iter().any(|n| n == "produce")) {
            return Err("no 'produce' span recorded".into());
        }
        if !shape_a
            .iter()
            .any(|(_, names)| names.iter().any(|n| n == "corruption-reencode"))
        {
            return Err("injected corruption left no trace instant".into());
        }
        Ok(())
    });
}

/// Tracing must be invisible to the data: under a worker kill inside the
/// respawn budget, the traced stream is byte-identical to the untraced
/// one — and the supervisor's respawn shows up as a trace instant.
#[test]
fn prop_traced_faulted_stream_is_byte_identical() {
    check_with("traced stream = untraced stream", 8, 0xBEEF, |rng| {
        let batches = 4 + rng.gen_range(6);
        (rng.next_u64(), batches, rng.gen_range(batches), 1 + rng.gen_range(3))
    }, |(seed, batches, panic_at, workers)| {
        let spec = FaultSpec::parse(&format!("seed={seed};worker-panic@{panic_at}"))
            .map_err(|e| e.to_string())?;
        let untraced = {
            let inj = Some(Arc::new(FaultInjector::new(&spec)));
            drain(loader_with(*seed, *batches, *workers, inj, Tracer::disabled()))?
        };
        let tracer = Tracer::enabled();
        let traced = {
            let inj = Some(Arc::new(FaultInjector::new(&spec)));
            drain(loader_with(*seed, *batches, *workers, inj, tracer.clone()))?
        };
        if untraced != traced {
            return Err(format!(
                "stream changed under tracing (workers={workers}, panic@{panic_at})"
            ));
        }
        let log = tracer.drain();
        let respawns = log
            .tracks
            .iter()
            .flat_map(|t| &t.events)
            .filter(|e| matches!(e.kind, EventKind::Instant) && e.name == "worker-respawn")
            .count();
        if respawns != 1 {
            return Err(format!("expected 1 worker-respawn instant, saw {respawns}"));
        }
        Ok(())
    });
}

/// A full per-thread buffer must drop (and count) events, never grow —
/// this is the no-hot-path-allocation guarantee at steady state.
#[test]
fn full_buffer_drops_instead_of_growing() {
    let tr = Tracer::with_capacity(16);
    let mut t = tr.thread("hot");
    let cap = t.capacity();
    assert!(cap >= 16);
    for _ in 0..100 {
        let t0 = t.begin();
        t.end_span("spin", "bench", t0);
    }
    assert_eq!(t.capacity(), cap, "hot-path push grew the buffer");
    assert_eq!(t.len(), cap);
    assert_eq!(t.dropped(), 100 - cap as u64);
    t.finish();
    let log = tr.drain();
    assert_eq!(log.event_count(), cap);
    assert_eq!(log.dropped(), 100 - cap as u64);
}

/// The Chrome export of a real traced run parses back as JSON and its
/// `produce` spans survive the round trip through the drift reader.
#[test]
fn chrome_export_round_trips_through_the_drift_reader() {
    let tracer = Tracer::enabled();
    let stream = drain(loader_with(11, 6, 2, None, tracer.clone())).unwrap();
    assert_eq!(stream.len(), 6);
    let log = tracer.drain();
    let produce_spans = log
        .tracks
        .iter()
        .flat_map(|t| &t.events)
        .filter(|e| matches!(e.kind, EventKind::Span { .. }) && e.name == "produce")
        .count();
    assert_eq!(produce_spans, 6, "one produce span per batch");
    let doc = Json::parse(&log.to_chrome_json().to_string()).expect("export is valid JSON");
    let observed = optorch::trace::observed_span_histogram(&doc, "produce");
    assert_eq!(observed.count(), 6);
}
