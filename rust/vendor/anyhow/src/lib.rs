//! Minimal, dependency-free shim of the `anyhow` API surface used by
//! `optorch`: [`Error`], [`Result`], the [`Context`] extension trait and the
//! `anyhow!` / `bail!` macros.
//!
//! Semantics match upstream closely enough for this crate's usage:
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`,
//!   carrying its `source()` chain;
//! * `Display` prints the outermost message, `{:#}` prints the whole chain
//!   joined with `": "` (upstream's alternate formatting);
//! * `context`/`with_context` push a new outermost message.
//!
//! The one intentional liberalization: [`Context`] is implemented for any
//! `Result<T, E: Display>` (upstream requires `E: std::error::Error`), which
//! lets string-error internals chain without wrapper types.

/// Error: an ordered chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` produces).
    pub fn msg<M: std::fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Push a new outermost context message.
    pub fn push_context<C: std::fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`; that is what
// makes the blanket `From` below coherent (same trick as upstream anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: std::fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: std::fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).push_context(context))
    }

    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: std::fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`] built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing thing"));
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(e.root_message(), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing thing");
    }

    #[test]
    fn with_context_lazy() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: inner");
    }

    #[test]
    fn option_context() {
        let o: Option<u32> = None;
        assert_eq!(o.context("nothing there").unwrap_err().to_string(), "nothing there");
    }

    #[test]
    fn macros() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let n = 7;
        let e = anyhow!("got {n} and {}", 8);
        assert_eq!(e.to_string(), "got 7 and 8");
        let e = anyhow!(String::from("owned"));
        assert_eq!(e.to_string(), "owned");
        fn bails() -> Result<()> {
            bail!("bad {}", "news");
        }
        assert_eq!(bails().unwrap_err().to_string(), "bad news");
    }

    #[test]
    fn debug_prints_chain() {
        let e = Error::msg("inner").push_context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by") && dbg.contains("inner"));
    }
}
