//! Stub of the `xla` crate (0.1.x API surface used by `optorch`).
//!
//! Host-side [`Literal`] construction/inspection works (enough for payload
//! marshaling code and its unit tests); everything touching a real PJRT
//! backend — [`PjRtClient::cpu`], compilation, execution — returns
//! [`Error`] with a pointer at the swap instructions. Replace this path
//! dependency with the upstream `xla` crate to run real training.

const STUB_MSG: &str = "xla stub: PJRT backend not available in this build — \
    replace rust/vendor/xla-stub with the real `xla` crate (see rust/README.md)";

/// Stub error (implements `std::error::Error`, unlike optorch's anyhow shim
/// error, so it flows through `?` and `.context(...)`).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err<T>() -> Result<T> {
    Err(Error(STUB_MSG.to_string()))
}

/// XLA element types (the real crate splits `PrimitiveType`/`ElementType`;
/// the stub aliases them).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    F16,
    F32,
    F64,
    U32,
    U64,
}

pub type ElementType = PrimitiveType;

impl PrimitiveType {
    fn byte_size(self) -> usize {
        match self {
            PrimitiveType::F16 => 2,
            PrimitiveType::F32 | PrimitiveType::U32 => 4,
            PrimitiveType::F64 | PrimitiveType::U64 => 8,
        }
    }
}

/// Rust scalar types a [`Literal`] can be built from / read back as.
pub trait NativeType: Copy {
    const TY: PrimitiveType;
    fn to_f64(self) -> f64;
    fn from_f64(v: f64) -> Self;
}

macro_rules! native {
    ($t:ty, $ty:expr) => {
        impl NativeType for $t {
            const TY: PrimitiveType = $ty;
            fn to_f64(self) -> f64 {
                self as f64
            }
            fn from_f64(v: f64) -> Self {
                v as Self
            }
        }
    };
}

native!(f32, PrimitiveType::F32);
native!(f64, PrimitiveType::F64);
native!(u32, PrimitiveType::U32);
native!(u64, PrimitiveType::U64);

/// Host tensor: values are held widened to f64; the tag tracks the logical
/// element type (adequate for marshaling-shape tests, not for bit-exact
/// numerics — which only matter beyond the stub boundary anyway).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    ty: PrimitiveType,
    dims: Vec<i64>,
    values: Vec<f64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            ty: T::TY,
            dims: vec![v.len() as i64],
            values: v.iter().map(|x| x.to_f64()).collect(),
        }
    }

    /// Rank-0 f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal { ty: PrimitiveType::F32, dims: vec![], values: vec![v as f64] }
    }

    pub fn reshape(mut self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.values.len() {
            return Err(Error(format!(
                "reshape to {dims:?} ({want} elems) from {} elems",
                self.values.len()
            )));
        }
        self.dims = dims.to_vec();
        Ok(self)
    }

    pub fn convert(&self, ty: PrimitiveType) -> Result<Literal> {
        let mut out = self.clone();
        out.ty = ty;
        Ok(out)
    }

    pub fn element_count(&self) -> usize {
        self.values.len()
    }

    pub fn size_bytes(&self) -> usize {
        self.values.len() * self.ty.byte_size()
    }

    pub fn ty(&self) -> Result<ElementType> {
        Ok(self.ty)
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        match self.values.first() {
            Some(&v) => Ok(T::from_f64(v)),
            None => Err(Error("get_first_element on empty literal".into())),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.values.iter().map(|&v| T::from_f64(v)).collect())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        stub_err()
    }
}

/// Parsed HLO module (stub: never constructible — parsing needs the backend).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        stub_err()
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub_err()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err()
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err()
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.size_bytes(), 16);
        assert_eq!(l.ty().unwrap(), ElementType::F32);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.0);
    }

    #[test]
    fn reshape_checks_count() {
        assert!(Literal::vec1(&[1.0f32; 3]).reshape(&[2, 2]).is_err());
    }

    #[test]
    fn convert_retags() {
        let l = Literal::vec1(&[1.0f32; 4]).convert(PrimitiveType::F16).unwrap();
        assert_eq!(l.size_bytes(), 8);
    }

    #[test]
    fn backend_paths_error() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}
